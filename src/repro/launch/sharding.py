"""Per-architecture sharding rules (DP / TP / EP / FSDP / SP), DESIGN.md §4.

Policy summary
--------------
* batch dims        -> ('pod','data')   (pod axis = pure DP; only grad
                                         all-reduce crosses the DCN)
* attention heads   -> 'model'          (q-head axis; configs pad head counts
                                         to a multiple of the TP degree; KV is
                                         replicated for GQA, sharded for MHA)
* MLP hidden (ff)   -> 'model'          (Megatron column/row parallel pair)
* MoE experts (E)   -> 'model'          (expert parallelism; dispatch/combine
                                         all-to-alls inserted by GSPMD)
* SSM inner dim     -> 'model'          iff ssm head count divides TP degree
                                         (mamba2-130m: too small, DP-only)
* vocab (lm_head V) -> 'model'          iff divisible, else contracted-d shard
* FSDP (cfg.fsdp)   -> 'data' on the non-TP weight dim of big archs
                        (weights all-gathered per use; ZeRO-3 style)
* optimizer moments -> same spec as their weight (adafactor vr/vc inherit the
                        reduced spec); masks/neuron_active follow weights
* KV caches         -> batch over ('pod','data'); sequence (S) over 'model'
                        (flash-decode style SP — kv-head counts rarely divide
                        the TP degree); for global_batch==1 (long_500k) batch
                        is unsharded and S shards over ('data','model')

Everything below is *rules*, applied to pytrees by path — there is no
hand-written per-arch table to drift out of sync.
"""
from __future__ import annotations

from repro.compat import NamedSharding, PartitionSpec as P


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def _map_with_path(fn, tree, path=(), fmt=None):
    """Walk a pytree calling ``fn(path, leaf, fmt)`` per leaf. ``fmt`` is the
    enclosing serving-format instance when the leaf is one of its array
    fields (None elsewhere) — the TP rules need the format's static shard
    count, which the bare path/leaf pair cannot carry."""
    if isinstance(tree, dict):
        return {k: _map_with_path(fn, v, path + (k,), fmt)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        return type(tree)(_map_with_path(fn, v, path + (f"#{i}",), fmt)
                          for i, v in enumerate(tree))
    if hasattr(tree, "_fields"):
        return type(tree)(**{k: _map_with_path(fn, getattr(tree, k),
                                               path + (k,), fmt)
                             for k in tree._fields})
    if isinstance(tree, _formats().SparseFormat):
        # serving-format pytree node: map each array field under its field
        # name (the same path layout the legacy dict leaves had, so the
        # values/indices rules below keep applying); static fields ride along
        return tree.map_arrays_with_names(
            lambda name, leaf: _map_with_path(fn, leaf, path + (name,), tree))
    return fn(path, tree, fmt)


def _formats():
    from repro.sparse import formats  # lazy: keeps launch importable alone
    return formats


# weight-name classes -------------------------------------------------------

_COL_PARALLEL = ("wq", "wk", "wv", "w_gate", "w_up", "in_z", "in_x")  # (d_in, tp_out)
_ROW_PARALLEL = ("wo", "w_down", "out_proj")                          # (tp_in, d_out)
_REPL = ("ln", "ln1", "ln2", "q_norm", "k_norm", "final_norm", "norm_scale",
         "in_bc", "in_dt", "conv_bc", "conv_b", "conv_bc_b", "a_log", "d_skip",
         "dt_bias", "router", "mu", "count")


class ShardingRules:
    def __init__(self, cfg, mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = mesh.shape["model"]
        self.fsdp_ax = "data" if getattr(cfg, "fsdp", False) else None
        # TP feasibility per dimension family
        self.attn_tp = _div(cfg.n_heads_padded, self.tp)
        self.kv_tp = _div(cfg.n_kv_heads_padded, self.tp)
        self.ff_tp = _div(cfg.d_ff, self.tp) if cfg.d_ff else False
        self.ep_tp = _div(cfg.n_experts, self.tp) if cfg.n_experts else False
        self.ssm_tp = (cfg.ssm_state > 0 and _div(cfg.ssm_n_heads, self.tp))
        self.vocab_tp = _div(cfg.vocab_padded, self.tp)
        self.dmodel_tp = _div(cfg.d_model, self.tp)

    # -- parameter specs ----------------------------------------------------
    def param_spec(self, path: tuple, leaf, fmt=None) -> P:
        cfg = self.cfg
        name = path[-1]
        ndim = leaf.ndim if hasattr(leaf, "ndim") else len(leaf.shape)
        is_expert = cfg.n_experts > 0 and name in ("w_gate", "w_up", "w_down")

        fmt_tp = getattr(fmt, "tp", 1) if fmt is not None else 1
        if fmt_tp > 1:
            # shard-blocked TP export: every per-neuron array is organized
            # in fmt.tp contiguous blocks along its neuron/active-row axis
            # with LOCALLY rebased out_index/active_index, so the block axis
            # shards over 'model' — each device holds exactly its block and
            # the format's vmap-over-blocks apply is shard-local end to end
            if self.tp > 1 and fmt_tp != self.tp:
                raise ValueError(
                    f"format at {'/'.join(map(str, path[:-1]))} was exported "
                    f"for tp={fmt_tp} shards but the mesh's model axis has "
                    f"{self.tp} devices — re-export with tp_shards={self.tp}")
            tp_ax = "model" if fmt_tp == self.tp else None
            if name == "values" and isinstance(fmt,
                                               _formats().StructuredFanIn):
                # quantized structured panel (lead..., d_in, tp * a_pad):
                # the COLUMN axis carries the blocks
                return P(*([None] * (ndim - 2) + [None, tp_ax]))
            if name in ("values", "indices"):
                # condensed family (lead..., n, k): neuron rows over model
                return P(*([None] * (ndim - 2) + [tp_ax, None]))
            if name in ("scales", "out_index", "active_index",
                        "neuron_active"):
                # per-neuron vectors: blocked along the last axis (the index
                # vectors are LOCAL under TP, so sharding them is valid —
                # unlike the replicated global-layout vectors below)
                return P(*([None] * (ndim - 1) + [tp_ax]))

        if name == "embed":
            # (V, d) [audio: (K, V, d); vit: (1, d)] — d over model; pure-DP
            # archs keep everything replicated (the model axis carries batch,
            # and a d-sharded embed would steal it back via the gather output)
            tp = "model" if (self.dmodel_tp and not self.pure_dp) else None
            return P(*([None] * (ndim - 1) + [tp]))
        if name == "lm_head":
            # (d, V) [audio: (K, d, V); vit: (d, classes)]
            if self.pure_dp:
                return P(*([None] * ndim))
            v = leaf.shape[-1]
            if _div(v, self.tp):
                return P(*([None] * (ndim - 2) + [self.fsdp_ax, "model"]))
            return P(*([None] * (ndim - 2) + ["model" if self.dmodel_tp else None, None]))

        if is_expert:
            lead = ndim - 3  # (..., E, a, b)
            ep = "model" if self.ep_tp else None
            if name == "w_down":  # (E, ff, d)
                return P(*([None] * lead + [ep, None, self.fsdp_ax]))
            return P(*([None] * lead + [ep, self.fsdp_ax, None]))

        if name in _COL_PARALLEL:
            lead = ndim - 2
            if name in ("in_z", "in_x"):
                tp = "model" if self.ssm_tp else None
            elif name in ("wk", "wv"):
                tp = "model" if self.kv_tp else None
            elif name == "wq":
                tp = "model" if self.attn_tp else None
            else:
                tp = "model" if self.ff_tp else None
            return P(*([None] * lead + [self.fsdp_ax, tp]))
        if name in _ROW_PARALLEL:
            lead = ndim - 2
            if name == "out_proj":
                tp = "model" if self.ssm_tp else None
            elif name == "wo":
                tp = "model" if self.attn_tp else None
            else:
                tp = "model" if self.ff_tp else None
            return P(*([None] * lead + [tp, self.fsdp_ax]))
        if name == "conv_x":  # (L, width, d_inner)
            return P(*([None] * (ndim - 1) + ["model" if self.ssm_tp else None]))
        if name == "mask" and len(path) >= 2:
            # MaskedDense serving leaf: same (lead..., d_in, d_out) shape as
            # its weight, so it shards exactly like the weight (the legacy
            # bare-bool masked leaf sat AT the stack path and inherited the
            # weight spec; the format's field must not lose that)
            return self.param_spec(path[:-1] + (path[-2],), leaf)
        if name in ("values", "indices"):
            # condensed stacks (lead..., d_out, k): neuron axis follows the
            # dense weight's OUT-dim sharding; k local
            parent = path[-2] if len(path) >= 2 else ""
            wspec = self.param_spec(path[:-1] + (parent,),
                                    _ShapeView(leaf.shape[:-1] + (1,)))
            out_ax = wspec[-1] if len(wspec) else None
            return P(*([None] * (ndim - 2) + [out_ax, None]))
        if name in _REPL or ndim <= 1:
            return P(*([None] * ndim))
        # everything else replicates — including the ablation index vectors
        # (out_index / active_index): their entries address the DENSE output
        # axis (scatter targets / gathered columns), so a shard of the
        # vector would still reference columns on every output shard
        return P(*([None] * ndim))

    def params(self, params_tree):
        return _map_with_path(
            lambda p, l, f: NamedSharding(self.mesh, self.param_spec(p, l, f)),
            params_tree)

    # -- sparsity state -------------------------------------------------------
    def masks(self, masks_tree):
        """Masks shard exactly like their weights; serving-format leaves
        shard per format (TP exports put their block axis over 'model')."""
        return _map_with_path(
            lambda p, l, f: NamedSharding(self.mesh, self.param_spec(p, l, f)),
            masks_tree)

    def neuron_active(self, active_tree, masks_tree=None):
        """neuron_active (lead..., d_out) inherits the weight's output-dim axis."""
        def spec(path, leaf, fmt=None):
            ndim = len(leaf.shape)
            # view with the weight's (d_in, d_out) rank so param_spec applies
            wspec = self.param_spec(path, _ShapeView(leaf.shape[:-1] + (1,) + leaf.shape[-1:]))
            out_axis = wspec[-1] if len(wspec) >= 1 else None
            return NamedSharding(self.mesh, P(*([None] * (ndim - 1) + [out_axis])))
        return _map_with_path(spec, active_tree)

    # -- optimizer state ------------------------------------------------------
    def opt_state(self, opt_tree, params_tree):
        """Moments follow their weight; adafactor factored stats drop an axis."""
        param_specs = _map_with_path(lambda p, l, f: self.param_spec(p, l, f),
                                     params_tree)

        def _drop_axis(spec, ax):
            if not isinstance(spec, P):
                return spec
            s = list(spec)
            if len(s) >= abs(ax):
                del s[ax]
            return P(*s)

        def rec(opt, pspec):
            if isinstance(opt, dict) and set(opt) <= {"vr", "vc", "v"}:
                out = {}
                if "vr" in opt:
                    out["vr"] = NamedSharding(self.mesh, _drop_axis(pspec, -1))
                if "vc" in opt:
                    out["vc"] = NamedSharding(self.mesh, _drop_axis(pspec, -2))
                if "v" in opt:
                    out["v"] = NamedSharding(self.mesh, pspec if isinstance(pspec, P) else P())
                return out
            if isinstance(opt, dict):
                return {k: rec(opt[k],
                               pspec[k] if isinstance(pspec, dict) and k in pspec else pspec)
                        for k in opt}
            if isinstance(pspec, P):
                return NamedSharding(self.mesh, pspec)
            return NamedSharding(self.mesh, P())

        out = {}
        for k, v in opt_tree.items():
            if k == "count":
                out[k] = NamedSharding(self.mesh, P())
            elif k in ("mu", "nu", "v"):
                out[k] = rec(v, param_specs)
            else:
                out[k] = rec(v, param_specs)
        return out

    # -- DST topology-update compute layout ------------------------------------
    def dst_compute_specs(self, registry) -> dict:
        """Per-layer slab PartitionSpec for each sparse stack's DST update.

        The update sorts along fan-in (d_in) per neuron, so the slab layout
        puts 'model' on the NEURON axis (d_out) — shard-local sorts, zero
        collectives in the selection (the constant fan-in insight, DESIGN §3).
        Expert stacks keep E on 'model' (per-expert updates are independent).
        """
        out = {}
        for s in registry:
            n_lead_rest = max(len(s.lead) - 1, 0)
            is_expert = self.cfg.n_experts > 0 and s.path[-1] in (
                "w_gate", "w_up", "w_down") and s.lead and s.lead[-1] == self.cfg.n_experts
            if is_expert:
                # slab (E, d_in, d_out): E over model
                out[s.name] = P("model" if self.ep_tp else None, None, None)
            else:
                tp = "model" if _div(s.d_out, self.tp) else None
                out[s.name] = P(*([None] * n_lead_rest + [None, tp]))
        return out

    # -- batches / activations ------------------------------------------------
    @property
    def pure_dp(self) -> bool:
        """No tensor parallelism anywhere -> the 'model' axis is free for DP."""
        return not (self.attn_tp or self.ff_tp or self.ep_tp or self.ssm_tp)

    def batch_axes(self, global_batch: int | None = None) -> tuple:
        base = ("pod", "data") if "pod" in self.mesh.axis_names else ("data",)
        candidates = [base]
        if self.pure_dp:
            candidates.insert(0, base + ("model",))
        candidates.append(())
        for cand in candidates:
            n = 1
            for a in cand:
                n *= self.mesh.shape[a]
            if global_batch is None or (n and _div(global_batch, n)):
                return cand
        return ()

    def batch(self, batch_tree, *, shape=None):
        bsz = shape.global_batch if shape is not None else None
        bax = self.batch_axes(bsz)

        def spec(path, leaf, fmt=None):
            nd = len(leaf.shape)
            name = path[-1]
            if name == "mrope_positions":  # (3, B, T)
                return NamedSharding(self.mesh, P(None, bax if bax else None))
            if name == "labels":  # (B,)
                return NamedSharding(self.mesh, P(bax if bax else None))
            return NamedSharding(self.mesh,
                                 P(*((bax if bax else None,) + (None,) * (nd - 1))))
        return _map_with_path(spec, batch_tree)

    # -- decode caches ----------------------------------------------------------
    def cache_spec(self, path, leaf, *, global_batch: int) -> P:
        bax = self.batch_axes(global_batch)
        batch_sharded = bool(bax)
        if "model" in bax:  # pure-DP arch: model axis taken by batch
            seq_ax = None
        else:
            seq_ax = "model" if batch_sharded else (
                *(("pod", "data") if "pod" in self.mesh.axis_names else ("data",)),
                "model")  # B=1: SP over everything
        nd = len(leaf.shape)
        name = path[-1]
        if name == "len":
            return P()
        b_ax = bax if batch_sharded else None
        if name in ("k", "v"):
            # (lead..., B, S, Hkv, D): S sharded (flash-decode SP)
            lead = nd - 4
            s = leaf.shape[-3]
            sx = seq_ax if _div(s, _axsize(self.mesh, seq_ax)) else None
            return P(*([None] * lead + [b_ax, sx, None, None]))
        if name in ("pk", "pv"):
            # paged pool (lead..., P, bs, Hkv, D): the PAGE axis carries the
            # batch parallelism — pages are per-stream, so sharding pages
            # over the batch axes is the paged analog of batch sharding;
            # the within-page token axis stays local (block scatters are
            # page-addressed)
            lead = nd - 4
            p = leaf.shape[-4]
            px = bax if (batch_sharded
                         and _div(p, _axsize(self.mesh, bax))) else None
            return P(*([None] * lead + [px, None, None, None]))
        if name == "h":  # SSM state (lead..., B, H, P, N): N over model
            lead = nd - 4
            n = leaf.shape[-1]
            sx = "model" if (_div(n, self.tp) and "model" not in (b_ax or ())) else None
            return P(*([None] * lead + [b_ax, None, None, sx]))
        if name == "conv_x":  # (lead..., B, w-1, d_inner)
            lead = nd - 3
            sx = "model" if (self.ssm_tp and "model" not in (b_ax or ())) else None
            return P(*([None] * lead + [b_ax, None, sx]))
        if name == "conv_bc":
            lead = nd - 3
            return P(*([None] * lead + [b_ax, None, None]))
        return P(*([None] * nd))

    def cache(self, cache_tree, *, global_batch: int):
        return _map_with_path(
            lambda path, leaf, fmt: NamedSharding(
                self.mesh, self.cache_spec(path, leaf, global_batch=global_batch)),
            cache_tree)


def _axsize(mesh, ax) -> int:
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


class _ShapeView:
    """Minimal leaf stand-in carrying only .shape/.ndim."""

    def __init__(self, shape):
        self.shape = tuple(shape)
        self.ndim = len(self.shape)
