"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device while
dryrun.py boots with 512 forced host devices. Mesh creation goes through
repro.compat so the same code runs on JAX 0.4.x (no AxisType) and current.
"""
from __future__ import annotations

from repro import compat


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (pod axis = pure DP/DCN)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh on the local device (CPU tests/examples)."""
    return compat.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def model_size(mesh) -> int:
    return mesh.shape["model"]


def data_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
