"""Programmatic serving engine: a continuous-batching request scheduler.

``ServingEngine`` is the API the serve CLI, the benchmarks and the examples
drive; it owns the pieces that used to be hand-wired per caller:

* **Admission** — ``submit(prompts, gen_len)`` validates a request (a batch
  of int token streams in ``[0, vocab)``) and queues it.
* **Grouping by plan key** — pending requests are grouped by ``PlanKey``:
  the request's BATCH BUCKET (``autotune.BATCH_BUCKETS`` — the same buckets
  that key the kernel autotune cache, so a group's tuned blocks and its
  plan are calibrated for each other) crossed with the per-stack FORMAT
  signature the cost model picks at that bucket. One execution ``Plan``
  (serving pytree of ``repro.sparse.formats`` objects) is built lazily per
  key and shared by every request the key ever groups.
* **Execution** — ``step()`` SCHEDULES rather than fuses: every dispatch is
  padded to the group's batch bucket (and prompts to their power-of-two
  bucket), so ONE compiled prefill program per (bucket, prompt bucket) and
  one decode program per (bucket, gen chunk) serve every request the key
  ever groups — a slab can never exceed its bucket because the bucket IS
  the dispatch shape. KV state lives in a paged pool (``repro.models.paged``:
  per-stream block tables over shared pages; idle rows point at the reserved
  garbage page 0), so requests are admitted at chunk boundaries into a
  RUNNING generation and finished streams free their pages mid-flight — no
  cache copies, no recompiles, no waiting for the slowest stream. Greedy
  decode is batch-row independent and masked pad slots contribute exact
  zeros, so a request's tokens are bitwise identical whether it runs alone,
  padded, or beside strangers admitted mid-generation.
* **Retirement** — ``retire()`` pops finished ``Result``s (tokens + timings
  + a ``cold`` flag when a dispatch compiled inside the timed window;
  ``warm=True`` pre-compiles new program signatures on garbage pages so SLA
  timings never include XLA compiles); ``refresh(params, masks,
  mask_versions)`` propagates a training job's incremental export into
  every cached plan.

Architectures outside ``model.supports_paged`` (windowed/ring caches, M-RoPE,
audio, SSM state) — or ``paged=False`` — use the legacy slab path: requests
sharing (prompt_len, gen_len) are concatenated and dispatched at their exact
shape, split so no slab exceeds its plan's bucket.

``repro.launch.serve`` is a thin CLI over this module; the jitted
prefill/decode primitives and the ``generate``/``serve_once`` helpers live
here so every consumer shares one compile cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import speculative as SP
from repro.models import model as M
from repro.models import paged as PG
from repro.sparse import autotune as AT
from repro.sparse import condensed as COND
from repro.sparse import formats as F
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG


# ---------------------------------------------------------------------------
# jitted execution primitives (module-level: one compile cache for all users)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill(cfg, params, masks, batch, cache):
    # module-level jit (not a per-call lambda) so repeated serve calls on the
    # same cfg/shapes hit the compile cache — benchmark warm-up relies on it
    return M.prefill_step(cfg, params, masks, batch, cache)


@functools.partial(jax.jit, static_argnames=("cfg", "gen_len"),
                   donate_argnums=(3,))
def _decode_loop(cfg, params, masks, cache, first_tok, gen_len: int):
    """Greedy decode of ``gen_len`` tokens as one scanned program.

    first_tok: (B, 1) int32 — argmax of the prefill logits. The cache is
    donated: each scan step's cache update aliases the input buffers, so
    serving memory stays at one cache regardless of generation length.
    Returns (B, gen_len) generated tokens (first_tok first).
    """
    def body(carry, _):
        cur, cache = carry
        logits, cache = M.decode_step(cfg, params, masks, {"tokens": cur}, cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, cache), cur[:, 0]

    (_, cache), toks = jax.lax.scan(body, (first_tok, cache), None,
                                    length=gen_len)
    return toks.T, cache


def _timed_serve(cfg, params, masks, prompts, gen_len: int):
    """One timed prefill+decode pass (the shared execution primitive).
    Returns (tokens (B, T+gen_len), prefill_s, decode_s, decode_tok_per_s)."""
    b, t = prompts.shape
    cache = M.init_cache(cfg, b, max_len=t + gen_len)

    t0 = time.perf_counter()
    logits, cache = _prefill(cfg, params, masks, {"tokens": prompts}, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    toks, _ = _decode_loop(cfg, params, masks, cache, first, gen_len)
    toks.block_until_ready()
    t_decode = time.perf_counter() - t0

    tok_s = b * gen_len / max(t_decode, 1e-9)
    return jnp.concatenate([prompts, toks], axis=1), t_prefill, t_decode, tok_s


def serve_once(cfg, params, masks, prompts, gen_len: int, path_name: str,
               quiet: bool = False):
    """One timed prefill+decode pass. Returns (tokens, decode_tok_per_s)."""
    out, t_prefill, t_decode, tok_s = _timed_serve(cfg, params, masks,
                                                   prompts, gen_len)
    if not quiet:
        b, t = prompts.shape
        print(f"[serve:{path_name}] prefill {b}x{t} in {t_prefill:.3f}s | "
              f"decode {b}x{gen_len} in {t_decode:.3f}s ({tok_s:.1f} tok/s)")
    return out, tok_s


def generate(cfg, params, masks, prompts: jax.Array, gen_len: int):
    """prompts: (B, T) int32. Greedy decode. Returns (B, T+gen_len)."""
    out, _ = serve_once(cfg, params, masks, prompts, gen_len, "generate",
                        quiet=True)
    return out


# ---------------------------------------------------------------------------
# paged (continuous-batching) execution primitives
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(4,))
def _paged_prefill(cfg, params, masks, batch, pool, table, prompt_lens):
    # one compiled program per (batch bucket, prompt bucket): every slab in
    # the bucket is padded to this shape, so the cache never misses per-slab
    return M.paged_prefill_step(cfg, params, masks, batch, pool, table,
                                prompt_lens)


@functools.partial(jax.jit, static_argnames=("cfg", "chunk"),
                   donate_argnums=(3,))
def _paged_decode_chunk(cfg, params, masks, pool, table, lengths, cur,
                        chunk: int):
    """``chunk`` greedy decode steps over the paged pool as one scanned
    program (pool donated). ``cur`` (B, 1) is each stream's next un-emitted
    token; returns (emitted (B, chunk), next cur, pool) — the same emission
    order as ``_decode_loop``, cut at chunk boundaries so the host can admit
    and retire streams between dispatches."""
    def body(carry, _):
        cur, pool, lens = carry
        logits, pool = M.paged_decode_step(cfg, params, masks,
                                           {"tokens": cur}, pool, table, lens)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, pool, lens + 1), cur[:, 0]

    (cur, pool, _), toks = jax.lax.scan(body, (cur, pool, lengths), None,
                                        length=chunk)
    return toks.T, cur, pool


def _jit_entries(fn) -> int:
    """Compiled-program count of a jitted function (-1 if the runtime does
    not expose it) — the cold-dispatch detector and the test hook for the
    one-program-per-bucket acceptance criterion."""
    try:
        return fn._cache_size()
    except Exception:  # noqa: BLE001 — optional introspection only
        return -1


def _paged_prefill_dispatch(cfg, params, tree, tokens, pool, table,
                            prompt_lens):
    """Timed prefill dispatch. Returns (logits, pool, seconds, cold)."""
    n0 = _jit_entries(_paged_prefill)
    t0 = time.perf_counter()
    logits, pool = _paged_prefill(cfg, params, tree, {"tokens": tokens},
                                  pool, table, prompt_lens)
    logits.block_until_ready()
    return (logits, pool, time.perf_counter() - t0,
            _jit_entries(_paged_prefill) != n0)


def _paged_decode_dispatch(cfg, params, tree, pool, table, lengths, cur,
                           chunk: int):
    """Timed decode-chunk dispatch. Returns (toks, cur, pool, secs, cold)."""
    n0 = _jit_entries(_paged_decode_chunk)
    t0 = time.perf_counter()
    toks, cur, pool = _paged_decode_chunk(cfg, params, tree, pool, table,
                                          lengths, cur, chunk)
    toks.block_until_ready()
    return (toks, cur, pool, time.perf_counter() - t0,
            _jit_entries(_paged_decode_chunk) != n0)


def _pow2_bucket(n: int) -> int:
    """Prompt-length bucket: next power of two (>= 1)."""
    b = 1
    while b < n:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# requests / plan keys / results
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """What makes two requests executable under one shared plan.

    ``batch_bucket`` — the autotune bucket the request's batch falls in
    (shared with the kernel tuning-cache keys, so the group's plan AND its
    tuned Pallas blocks come from the same calibration point).
    ``formats`` — the per-stack format signature the cost model picks at
    that bucket (registry order); a fixed ``path`` forces it uniform.
    ``tp`` — the mesh's model-axis size the group's plan shards over (1 on
    a single device / data-only mesh); part of the key because a sharded
    and a replicated plan of the same bucket compile different programs.
    """
    batch_bucket: int
    formats: tuple[tuple[str, str], ...]
    tp: int = 1

    def describe(self) -> str:
        reps = {r for _, r in self.formats}
        rep = reps.pop() if len(reps) == 1 else "mixed"
        tp_s = f"/tp{self.tp}" if self.tp > 1 else ""
        return f"b<={self.batch_bucket}/{rep}{tp_s}"


@dataclasses.dataclass
class Request:
    id: int
    prompts: jax.Array      # (B, T) int32
    gen_len: int


@dataclasses.dataclass
class Result:
    id: int
    tokens: jax.Array       # (B, T + gen_len) — prompt followed by greedy tokens
    plan_key: PlanKey
    prefill_s: float
    decode_s: float
    tok_s: float            # decode throughput of the slab this request ran in
    cold: bool = False      # a dispatch this request rode compiled in-line
                            # (never with warm=True — SLA timings stay clean)
    spec: dict | None = None    # speculative counters (SpecStats.summary)
                                # when the request decoded speculatively


@dataclasses.dataclass(frozen=True)
class GroupReport:
    """What one ``step()`` did for one plan-key group."""
    key: PlanKey
    request_ids: tuple[int, ...]    # requests ADMITTED during this step
    n_slabs: int            # program dispatches that admitted them (paged:
                            # bucket-padded prefills; legacy: exact slabs)
    total_batch: int


# ---------------------------------------------------------------------------
# paged runner: per-group scheduler state
# ---------------------------------------------------------------------------


_WARMED: set = set()        # (kind, cfg, path, key, shape...) signatures
                            # already pre-compiled by a warm dispatch


@dataclasses.dataclass
class _Active:
    """One in-flight request: which bucket rows it occupies, which pages it
    owns, and the tokens collected so far."""
    req: Request
    rows: list
    pages: list
    remaining: int
    prefill_s: float
    cold: bool
    toks: list = dataclasses.field(default_factory=list)
    decode_s: float = 0.0
    base_pages: int = 0     # admission page budget per row — the floor a
                            # speculative rewind never releases below
    spec: SP.SpecStats = dataclasses.field(default_factory=SP.SpecStats)


class _PagedRunner:
    """Device/host state for one plan-key group.

    Owns the shared page pool (device, donated through every dispatch) and
    the per-row host arrays (block tables, lengths, next tokens). Rows are
    bucket slots: every dispatch runs at the full ``key.batch_bucket``, idle
    rows carrying all-zero tables (the reserved garbage page) and length 0.
    """

    def __init__(self, eng: "ServingEngine", key: PlanKey):
        self.eng = eng
        self.key = key
        self.bucket = key.batch_bucket
        self.bs = eng.block_size
        self.nb = 0                     # table width (pages per stream)
        self.num_blocks = 1             # pool size incl. reserved page 0
        self.pool = None                # device {"pk","pv"} or None
        self.alloc = PG.BlockAllocator(1)
        self.table = np.zeros((self.bucket, 0), np.int32)
        self.lengths = np.zeros((self.bucket,), np.int32)
        self.cur = np.zeros((self.bucket, 1), np.int32)
        self.free_rows = list(range(self.bucket))
        self.active: dict[int, _Active] = {}

    # -- capacity -----------------------------------------------------------

    def _ensure_capacity(self, nb_needed: int, pages_needed: int) -> None:
        """Size (or grow) the pool so an admission of ``pages_needed`` fresh
        pages with table width ``nb_needed`` fits. Growth reshapes the pool
        (a recompile for this runner's programs — rare: only when a request
        needs more per-stream capacity than anything seen before); existing
        pages keep their ids, so in-flight streams are unaffected."""
        nb = max(self.nb, nb_needed)
        blocks = self.num_blocks
        if pages_needed > self.alloc.available or nb > self.nb or self.pool is None:
            blocks = max(self.num_blocks
                         + max(pages_needed - self.alloc.available, 0),
                         1 + self.bucket * nb)
        if self.pool is None:
            self.nb, self.num_blocks = nb, blocks
            self.pool = M.init_paged_pool(self.eng.cfg, blocks, self.bs)
            self.alloc = PG.BlockAllocator(blocks)
            self.table = np.zeros((self.bucket, nb), np.int32)
            return
        if nb > self.nb:
            self.table = np.concatenate(
                [self.table, np.zeros((self.bucket, nb - self.nb), np.int32)],
                axis=1)
            self.nb = nb
        if blocks > self.num_blocks:
            pad = blocks - self.num_blocks
            self.pool = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((a.shape[0], pad, *a.shape[2:]), a.dtype)],
                    axis=1),
                self.pool)
            self.alloc.grow(blocks)
            self.num_blocks = blocks

    # -- warm-up ------------------------------------------------------------

    def _warm(self, kind: str, t_or_chunk: int) -> None:
        """Pre-compile a new program signature on garbage state (zero pool,
        all tables at the reserved page) so the first TIMED dispatch through
        it never includes the XLA compile."""
        eng = self.eng
        sig = (kind, eng.cfg, eng.path, self.key, t_or_chunk,
               self.nb, self.num_blocks, self.bs,
               eng.speculative if kind in ("draft", "verify") else None)
        if sig in _WARMED:
            return
        tree = eng.serving_tree_for(self.key)
        pool = M.init_paged_pool(eng.cfg, self.num_blocks, self.bs)
        table = jnp.zeros((self.bucket, self.nb), jnp.int32)
        lens = jnp.zeros((self.bucket,), jnp.int32)
        if kind == "prefill":
            _paged_prefill_dispatch(
                eng.cfg, eng.params, tree,
                jnp.zeros((self.bucket, t_or_chunk), jnp.int32), pool, table,
                lens)
        elif kind == "draft":
            SP.draft_dispatch(
                eng.cfg, eng.params, eng.draft_tree_for(self.key), pool,
                table, lens, jnp.zeros((self.bucket, 1), jnp.int32),
                t_or_chunk)
        elif kind == "verify":
            SP.verify_dispatch(
                eng.cfg, eng.params, tree, pool, table, lens,
                jnp.zeros((self.bucket, t_or_chunk + 1), jnp.int32))
        else:
            _paged_decode_dispatch(
                eng.cfg, eng.params, tree, pool, table, lens,
                jnp.zeros((self.bucket, 1), jnp.int32), t_or_chunk)
        _WARMED.add(sig)

    # -- admission ----------------------------------------------------------

    def admit(self, pending: list[Request]) -> list[Request]:
        """Admit a FIFO prefix of ``pending`` into free rows with ONE
        bucket-padded prefill dispatch. Prompts are right-padded to the
        admitted set's power-of-two prompt bucket; idle rows (live streams
        mid-decode included) get all-zero tables so the prefill cannot touch
        their pages. Returns the admitted requests (possibly empty); on a
        failed dispatch all bookkeeping is rolled back and nothing is
        admitted."""
        chosen, rows_needed = [], 0
        for r in pending:
            b = r.prompts.shape[0]
            if rows_needed + b > len(self.free_rows):
                break
            chosen.append(r)
            rows_needed += b
        if not chosen:
            return []

        eng = self.eng
        t_bucket = max(_pow2_bucket(r.prompts.shape[1]) for r in chosen)
        # per-stream page budget: prompt bucket + generation, NO chunk
        # slack. A stream that finishes mid-chunk rides the chunk out
        # writing garbage tokens; those positions clamp into its own last
        # page (paged_cache_write), whose real slots it no longer needs —
        # every token it will EMIT was computed before the overshoot, and
        # its pages are released at chunk end. Keeping capacity tight keeps
        # the attention span (nb * bs) at the contiguous cache's size.
        per_row = {r.id: PG.pages_for(t_bucket + r.gen_len, self.bs)
                   for r in chosen}
        # speculative mode: the table is WIDER than the page budget — the
        # extra gamma slots map draft/verify overshoot to entries that are
        # either best-effort page grants (rewound each round) or zero
        # (clamping writes into the garbage page, commit capped to match)
        nb_width = max(per_row.values())
        if eng.speculative is not None:
            nb_width = max(PG.pages_for(t_bucket + r.gen_len
                                        + eng.speculative.gamma, self.bs)
                           for r in chosen)
        self._ensure_capacity(
            nb_width,
            sum(per_row[r.id] * r.prompts.shape[0] for r in chosen))
        if eng.warm:
            self._warm("prefill", t_bucket)

        tokens = np.zeros((self.bucket, t_bucket), np.int32)
        prefill_table = np.zeros((self.bucket, self.nb), np.int32)
        prompt_lens = np.zeros((self.bucket,), np.int32)
        admitted: list[_Active] = []
        try:
            for r in chosen:
                b, t = r.prompts.shape
                rows = [self.free_rows.pop(0) for _ in range(b)]
                prompts_np = np.asarray(r.prompts)
                pages_all: list[int] = []
                for i, row in enumerate(rows):
                    pages = self.alloc.alloc(per_row[r.id])
                    pages_all.extend(pages)
                    self.table[row, :] = 0
                    self.table[row, :len(pages)] = pages
                    prefill_table[row] = self.table[row]
                    tokens[row, :t] = prompts_np[i]
                    prompt_lens[row] = t
                admitted.append(_Active(req=r, rows=rows, pages=pages_all,
                                        remaining=r.gen_len, prefill_s=0.0,
                                        cold=False, base_pages=per_row[r.id]))
            tree = eng.serving_tree_for(self.key)
            logits, pool, dt, cold = _paged_prefill_dispatch(
                eng.cfg, eng.params, tree, jnp.asarray(tokens), self.pool,
                jnp.asarray(prefill_table), jnp.asarray(prompt_lens))
        except Exception:
            # roll back: nothing was admitted, the requests stay pending
            for a in admitted:
                self.alloc.release(a.pages)
                for row in a.rows:
                    self.table[row, :] = 0
                    self.free_rows.append(row)
            raise
        self.pool = pool
        first = np.asarray(jnp.argmax(logits, -1).astype(jnp.int32))
        for a in admitted:
            a.prefill_s = dt
            a.cold = cold
            for row in a.rows:
                self.cur[row, 0] = first[row]
                self.lengths[row] = prompt_lens[row]
            self.active[a.req.id] = a
        return [a.req for a in admitted]

    # -- decode -------------------------------------------------------------

    def decode_chunk(self) -> None:
        """One chunked decode dispatch over the full bucket. The chunk is
        adaptive — ``min(gen_chunk, longest remaining)`` — so a nearly-done
        group never pays for a full chunk; streams that finish inside the
        chunk are retired (pages freed, rows recycled) before the next one."""
        if not self.active:
            return
        eng = self.eng
        chunk = min(eng.gen_chunk,
                    max(a.remaining for a in self.active.values()))
        live = np.zeros((self.bucket,), bool)
        for a in self.active.values():
            live[a.rows] = True
        self.lengths[~live] = 0      # idle rows: writes pinned to page 0
        if eng.warm:
            self._warm("decode", chunk)
        tree = eng.serving_tree_for(self.key)
        toks, cur, pool, dt, cold = _paged_decode_dispatch(
            eng.cfg, eng.params, tree, self.pool, jnp.asarray(self.table),
            jnp.asarray(self.lengths), jnp.asarray(self.cur), chunk)
        self.pool = pool
        self.cur = np.array(cur)        # np.array: host copy stays writable
        toks = np.asarray(toks)
        self.lengths[live] += chunk
        for a in list(self.active.values()):
            take = min(chunk, a.remaining)
            a.toks.append(toks[a.rows, :take])
            a.remaining -= take
            a.decode_s += dt
            a.cold = a.cold or cold
            if a.remaining == 0:
                self._retire(a)

    # -- speculative rounds -------------------------------------------------

    def spec_round(self) -> None:
        """One speculative round over the full bucket: ``gamma`` draft
        steps (ablated subnetwork, shared weights), ONE batched
        full-network verify over the ``gamma + 1`` positions, host-side
        acceptance, and a paged rewind of everything the round wrote past
        each stream's new committed length. Commits are LOCKSTEP within a
        request (its rows share one remaining counter): every row commits
        ``min`` over rows of (its accepted prefix + 1), further capped by
        remaining and by held-page capacity — any cap below a row's own
        acceptance stays bitwise correct, it just re-derives the dropped
        suffix next round."""
        if not self.active:
            return
        eng = self.eng
        sc = eng.speculative
        gamma = sc.gamma
        draft_tree = eng.draft_tree_for(self.key)
        live = np.zeros((self.bucket,), bool)
        for a in self.active.values():
            live[a.rows] = True
        self.lengths[~live] = 0      # idle rows: writes pinned to page 0
        # best-effort overshoot grants: pages covering slots up to
        # L0 + gamma. A stream that gets none still makes progress — its
        # overshoot writes clamp into the garbage page and its commit is
        # capped at the capacity it does hold (>= 1: the admission budget
        # always covers the next committed token).
        for a in self.active.values():
            for row in a.rows:
                needed = PG.pages_for(int(self.lengths[row]) + gamma + 1,
                                      self.bs)
                held = int(np.count_nonzero(self.table[row]))
                if needed > held:
                    try:
                        extra = self.alloc.alloc(needed - held)
                    except RuntimeError:
                        continue
                    self.table[row, held:held + len(extra)] = extra
                    a.pages.extend(extra)
        if eng.warm:
            self._warm("draft", gamma)
            self._warm("verify", gamma)
        tree = eng.serving_tree_for(self.key)
        table_dev = jnp.asarray(self.table)
        lengths_dev = jnp.asarray(self.lengths)
        drafted, pool, dt_d, cold_d = SP.draft_dispatch(
            eng.cfg, eng.params, draft_tree, self.pool, table_dev,
            lengths_dev, jnp.asarray(self.cur), gamma)
        feed = jnp.concatenate(
            [jnp.asarray(self.cur), drafted], axis=1)       # (bucket, g+1)
        targ, pool, dt_v, cold_v = SP.verify_dispatch(
            eng.cfg, eng.params, tree, pool, table_dev, lengths_dev, feed)
        self.pool = pool
        feed_np = np.asarray(feed)
        targ_np = np.asarray(targ)
        drafted_np = np.asarray(drafted)
        for a in list(self.active.values()):
            commit, matched = a.remaining, 0
            for row in a.rows:
                m = 0
                while (m < gamma
                       and drafted_np[row, m] == targ_np[row, m]):
                    m += 1
                matched += m
                # only positions whose verify K/V landed in HELD pages have
                # correct logits (garbage-page overshoot attends junk)
                held = int(np.count_nonzero(self.table[row]))
                cap = held * self.bs - int(self.lengths[row])
                commit = min(commit, m + 1, cap)
            assert commit >= 1, "admission budget must cover the next token"
            a.toks.append(feed_np[a.rows, :commit])
            for row in a.rows:
                self.cur[row, 0] = targ_np[row, commit - 1]
                self.lengths[row] += commit
            a.remaining -= commit
            a.decode_s += dt_d + dt_v
            a.cold = a.cold or cold_d or cold_v
            a.spec.rounds += 1
            a.spec.drafted += gamma * len(a.rows)
            a.spec.matched += matched
            a.spec.committed += commit * len(a.rows)
            a.spec.draft_s += dt_d
            a.spec.verify_s += dt_v
            if a.remaining == 0:
                self._retire(a)
        # rewind: pages covering only rejected/overshoot slots go back to
        # the pool (never below the admission budget — the floor that
        # guarantees next round's commit capacity without re-allocating
        # under contention)
        for a in self.active.values():
            for row in a.rows:
                keep = max(int(self.lengths[row]), a.base_pages * self.bs)
                PG.rewind_pages(self.table[row], self.alloc, keep, self.bs)
            a.pages = [int(p) for row in a.rows
                       for p in self.table[row] if p != 0]

    def _retire(self, a: _Active) -> None:
        req = a.req
        gen = np.concatenate(a.toks, axis=1)
        out = jnp.concatenate(
            [jnp.asarray(req.prompts, jnp.int32), jnp.asarray(gen)], axis=1)
        b = req.prompts.shape[0]
        spec = (a.spec.summary(self.eng.speculative, b)
                if a.spec.rounds else None)
        self.eng._done[req.id] = Result(
            id=req.id, tokens=out, plan_key=self.key, prefill_s=a.prefill_s,
            decode_s=a.decode_s,
            tok_s=b * req.gen_len / max(a.decode_s, 1e-9), cold=a.cold,
            spec=spec)
        self.alloc.release(a.pages)
        for row in a.rows:
            self.table[row, :] = 0
            self.lengths[row] = 0
            self.free_rows.append(row)
        del self.active[req.id]


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Plan-keyed batch serving over a trained (params, masks) pair.

    >>> eng = ServingEngine(cfg, params, masks, registry, path="auto")
    >>> rid = eng.submit(prompts, gen_len=16)
    >>> eng.step()
    >>> [res] = eng.retire()

    ``path`` is any ``repro.sparse.plan.PATHS`` entry; ``"auto"`` lets each
    group's batch bucket pick per-stack formats by the cost model.
    ``profile`` prices those decisions (``HardwareProfile.measure()`` for a
    machine-calibrated one). Plans are built lazily per ``PlanKey`` at the
    BUCKET batch size and cached for the engine's lifetime; ``refresh``
    keeps them coherent with a live training job.

    ``paged=None`` auto-selects the continuous-batching paged scheduler
    when the architecture supports it (``model.supports_paged``), else the
    legacy exact-shape slab path. ``block_size`` is the paged-pool page
    size in tokens, ``gen_chunk`` the decode-dispatch granularity (streams
    join/leave at chunk boundaries), and ``warm=True`` pre-compiles every
    new program signature outside the timed window.

    ``values_dtype`` (``"bf16"``/``"int8"``/``"fp8"``; None keeps the param
    dtype) is an ENGINE-level setting, not part of ``PlanKey``: every plan
    this engine builds exports value-storing leaves at that width, the cost
    model prices the real stored bytes, and ``autotune`` times the quantized
    kernels under the matching cache keys. One engine serves one precision —
    a deployment that wants both runs two engines, exactly as it would for
    two checkpoints. Masked-dense stacks read the live params and are
    unaffected (quantized decode is a serving artifact of the exported
    formats).
    """

    def __init__(self, cfg, params, masks, registry=None, *,
                 path: str = "auto",
                 profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
                 mask_versions: dict | None = None,
                 paged: bool | None = None,
                 block_size: int = 16,
                 gen_chunk: int = 16,
                 warm: bool = True,
                 values_dtype: str | None = None,
                 mesh=None,
                 speculative: SP.SpecConfig | None = None):
        if path not in PLAN.PATHS:
            raise ValueError(
                f"unknown serving path {path!r}; expected one of {PLAN.PATHS}")
        if speculative is not None:
            if path == "masked":
                raise ValueError(
                    "speculative decoding needs a format-typed plan to "
                    "derive the draft from; the all-masked fast path serves "
                    "raw masks — pick any other path (or 'auto')")
            if paged is False or not M.supports_paged(cfg):
                raise ValueError(
                    "speculative decoding runs on the paged scheduler "
                    "(draft overshoot rollback is a page-table edit); this "
                    "architecture/config only supports the legacy slab path")
        if paged is None:
            paged = M.supports_paged(cfg)
        elif paged and not M.supports_paged(cfg):
            raise ValueError(
                "paged serving requires a causal architecture without "
                "windowed/ring caches, M-RoPE or SSM state "
                f"(family={cfg.family!r}); pass paged=None to auto-select "
                "or paged=False for the legacy slab path")
        if block_size < 1 or gen_chunk < 1:
            raise ValueError("block_size and gen_chunk must be >= 1")
        self.cfg = cfg
        self.params = params
        self.masks = masks or {}
        self.registry = list(REG.build_registry(cfg) if registry is None
                             else registry)
        self.path = path
        self.profile = profile
        self.paged = bool(paged)
        self.block_size = int(block_size)
        self.gen_chunk = int(gen_chunk)
        self.warm = bool(warm)
        self.values_dtype = F.resolve_quantize_spec(values_dtype)
        # tensor parallelism: a mesh with a model axis shards every plan's
        # condensed-family leaves over it (per-stack, collective-priced —
        # see plan.build_plan); no mesh or a size-1 model axis is the
        # single-device engine unchanged
        self.mesh = mesh
        self.tp = (int(mesh.shape["model"])
                   if mesh is not None and "model" in mesh.axis_names else 1)
        self._mask_versions = mask_versions
        self._itemsize = jnp.dtype(cfg.param_dtype).itemsize
        self._stats: dict | None = None     # realized stats, computed once
        self._plans: dict[PlanKey, PLAN.Plan] = {}
        # self-draft speculative decoding (repro.launch.speculative): draft
        # trees are derived lazily per plan key and invalidated whenever the
        # underlying plan's buffers move (refresh / sync adoption DONATE the
        # old arrays the draft leaves alias)
        self.speculative = speculative
        self._draft_trees: dict[PlanKey, object] = {}
        self._spec_estimates: dict[PlanKey, PLAN.SpecEstimate] = {}
        self._runners: dict[PlanKey, _PagedRunner] = {}
        self._pending: list[Request] = []
        self._done: dict[int, Result] = {}
        self._next_id = 0
        # live train->serve sync (repro.sync): a subscriber drained at
        # paged-chunk boundaries, applying published deltas through the
        # donated adoption path
        self._subscriber = None
        self._sync_generation: int | None = None
        self._sync_donate = True

    # -- stats / keys -------------------------------------------------------

    def stats(self) -> dict:
        """Realized per-stack export stats (one fused host sync, cached)."""
        if self._stats is None:
            self._stats = COND.export_stats(self.registry, self.masks)
        return self._stats

    def plan_key(self, batch_size: int) -> PlanKey:
        """The key a request of ``batch_size`` streams groups under: its
        batch bucket x the per-stack format signature at that bucket."""
        bucket = AT.batch_bucket(max(int(batch_size), 1))
        if self.path != "auto":
            sig = tuple((s.name, self.path) for s in self.registry)
            return PlanKey(batch_bucket=bucket, formats=sig, tp=self.tp)
        stats = self.stats()
        sig = tuple(
            (s.name, PLAN.select_representation(
                s, batch_size=bucket, itemsize=self._itemsize,
                stats=stats[s.name], profile=self.profile,
                values_dtype=self.values_dtype, tp=self.tp).representation)
            for s in self.registry)
        return PlanKey(batch_bucket=bucket, formats=sig, tp=self.tp)

    def plan_for(self, key: PlanKey) -> PLAN.Plan:
        """The (lazily built, cached) execution plan serving ``key``."""
        plan = self._plans.get(key)
        if plan is None:
            plan = PLAN.build_plan(
                self.cfg, self.registry, self.params, self.masks,
                batch_size=key.batch_bucket, path=self.path,
                mask_versions=self._mask_versions, profile=self.profile,
                values_dtype=self.values_dtype, tp=key.tp)
            if (self._subscriber is not None
                    and self._subscriber.generation is not None):
                # the local (params, masks) may lag the stream (sync only
                # rewrites stack leaves in EXISTING plans) — bring the
                # fresh plan straight to the subscribed generation
                self._apply_sync_to_plan(plan, self._subscriber, force=True)
            self._plans[key] = plan
        return plan

    def serving_tree_for(self, key: PlanKey):
        """The masks-slot pytree a group executes with. The all-masked fixed
        path serves the training-layout masks directly (identity — no
        export, the pre-engine ``--path masked`` fast path)."""
        if self.path == "masked":
            return self.masks
        return self.plan_for(key).serving_tree

    def draft_tree_for(self, key: PlanKey):
        """The (lazily derived, cached) DRAFT serving tree for ``key`` —
        the target plan at ``speculative.draft_ablation`` extra neuron
        ablation, sharing every value buffer with the target (asserted:
        zero extra weight bytes). Returns None when speculation is off or
        when ``path="auto"`` pricing declines it for this key (draft too
        slow / assumed acceptance too low) and ``force`` is unset; a fixed
        path runs what it was told. The cache is cleared whenever refresh
        or sync adoption donates the target buffers the draft aliases."""
        if self.speculative is None:
            return None
        if key in self._draft_trees:
            return self._draft_trees[key]
        sc = self.speculative
        plan = self.plan_for(key)
        tree, report = PLAN.derive_draft_tree(
            self.registry, plan.serving_tree, self.params, self.masks,
            sc.draft_ablation)
        shared, extra = PLAN.draft_weight_overhead_bytes(
            self.registry, plan.serving_tree, tree)
        assert extra == 0, (
            f"draft tree allocated {extra} value bytes; self-drafting "
            f"must share the target's weight residency ({report})")
        est = PLAN.price_speculation(
            self.registry, plan.serving_tree, tree,
            batch_size=key.batch_bucket, gamma=sc.gamma,
            acceptance=sc.acceptance, profile=self.profile)
        self._spec_estimates[key] = est
        if self.path == "auto" and not sc.force and not est.worthwhile:
            tree = None         # decline: the cost model says plain decode
                                # is faster at this bucket
        self._draft_trees[key] = tree
        return tree

    def spec_estimate_for(self, key: PlanKey) -> PLAN.SpecEstimate | None:
        """The pricing behind ``draft_tree_for``'s accept/decline (None
        until that key's draft has been derived)."""
        self.draft_tree_for(key)
        return self._spec_estimates.get(key)

    # -- request lifecycle --------------------------------------------------

    def submit(self, prompts, gen_len: int) -> int:
        """Queue a request: ``prompts`` (B, T) integer token ids in
        ``[0, vocab_size)``, decode ``gen_len`` greedy tokens per stream.
        Validates and casts to int32 at admission — a malformed request
        fails HERE with a readable error, not as a device-side gather of
        garbage rows three dispatches later. Returns the request id."""
        prompts = jnp.asarray(prompts)
        if prompts.ndim != 2 or 0 in prompts.shape:
            raise ValueError(f"prompts must be (batch, prompt_len) with both "
                             f"dims >= 1; got shape {prompts.shape}")
        if not jnp.issubdtype(prompts.dtype, jnp.integer):
            raise ValueError(
                f"prompts must be integer token ids, got dtype "
                f"{prompts.dtype}; cast explicitly if these are token ids")
        if gen_len < 1:
            raise ValueError("gen_len must be >= 1")
        lo, hi = int(prompts.min()), int(prompts.max())
        if lo < 0 or hi >= self.cfg.vocab_size:
            raise ValueError(
                f"token ids out of range: prompts span [{lo}, {hi}] but "
                f"vocab_size is {self.cfg.vocab_size}")
        prompts = prompts.astype(jnp.int32)
        rid = self._next_id
        self._next_id += 1
        self._pending.append(Request(id=rid, prompts=prompts,
                                     gen_len=int(gen_len)))
        return rid

    def pending_groups(self) -> dict[PlanKey, list[int]]:
        """Predicted grouping of the pending requests (no execution)."""
        groups: dict[PlanKey, list[int]] = {}
        for req in self._pending:
            groups.setdefault(self.plan_key(req.prompts.shape[0]),
                              []).append(req.id)
        return groups

    def step(self, quiet: bool = True,
             max_chunks: int | None = None) -> list[GroupReport]:
        """Advance serving, one plan-key group at a time.

        Paged (default where supported): each group's runner loops
        admit-then-decode — pending requests join free bucket rows at
        chunk boundaries (one bucket-padded prefill per admission wave) and
        each iteration decodes one adaptive chunk, retiring streams as they
        finish. With ``max_chunks=None`` the step drains the group
        completely; an event loop passes ``max_chunks=1`` to interleave
        admission with arrival (continuous batching). Results land in the
        retire queue.

        Legacy (``paged=False``): requests sharing (prompt_len, gen_len)
        fuse into exact-shape slabs, split at the bucket boundary so no
        dispatch exceeds ``key.batch_bucket``.
        """
        self._drain_sync()          # an idle engine still tracks the stream
        if not self.paged:
            return self._step_legacy(quiet)

        groups: dict[PlanKey, list[Request]] = {}
        for req in self._pending:
            groups.setdefault(self.plan_key(req.prompts.shape[0]),
                              []).append(req)
        keys = list(groups)
        for key, runner in self._runners.items():
            if key not in groups and runner.active:
                keys.append(key)        # drain groups with no new arrivals

        reports = []
        for key in keys:
            runner = self._runners.get(key)
            if runner is None:
                runner = self._runners[key] = _PagedRunner(self, key)
            admitted_ids: list[int] = []
            n_prefills = total_b = chunks = 0
            while True:
                # chunk boundary: published deltas land HERE, between
                # decode dispatches, never mid-scan — each chunk runs
                # against exactly one committed generation
                if chunks:
                    self._drain_sync()
                # requests leave the pending queue only once their prefill
                # has actually executed: an exception mid-step (plan build,
                # compile, OOM) must not silently drop queued work
                pend = [r for r in self._pending
                        if self.plan_key(r.prompts.shape[0]) == key]
                if pend and runner.free_rows:
                    admitted = runner.admit(pend)
                    if admitted:
                        served = {r.id for r in admitted}
                        self._pending = [r for r in self._pending
                                         if r.id not in served]
                        admitted_ids.extend(sorted(served))
                        n_prefills += 1
                        total_b += sum(r.prompts.shape[0] for r in admitted)
                        if not quiet:
                            print(f"[engine] group {key.describe()}: "
                                  f"admitted {len(admitted)} request(s) "
                                  f"({total_b} stream(s)) into bucket "
                                  f"{runner.bucket}")
                if not runner.active:
                    break
                if (self.speculative is not None
                        and self.draft_tree_for(key) is not None):
                    runner.spec_round()
                else:
                    runner.decode_chunk()
                chunks += 1
                if max_chunks is not None and chunks >= max_chunks:
                    break
            reports.append(GroupReport(
                key=key, request_ids=tuple(admitted_ids),
                n_slabs=n_prefills, total_batch=total_b))
        return reports

    def _step_legacy(self, quiet: bool = True) -> list[GroupReport]:
        """Exact-shape slab serving (architectures outside the paged path).

        Within a group, requests sharing (prompt_len, gen_len) are fused
        into batch slabs, each SPLIT at the plan's bucket boundary — the
        plan (and its tuned kernels) is calibrated at ``key.batch_bucket``,
        so a fused slab must never exceed it.
        """
        self._drain_sync()
        groups: dict[PlanKey, list[Request]] = {}
        for req in self._pending:
            groups.setdefault(self.plan_key(req.prompts.shape[0]),
                              []).append(req)

        reports = []
        for key, reqs in groups.items():
            # requests stay in the pending queue until their slab has
            # actually executed: an exception mid-step (plan build, compile,
            # OOM) must not silently drop queued work — unexecuted requests
            # remain pending for a later step()
            tree = self.serving_tree_for(key)
            slabs: dict[tuple[int, int], list[Request]] = {}
            for req in reqs:
                slabs.setdefault((req.prompts.shape[1], req.gen_len),
                                 []).append(req)
            n_dispatch = 0
            for (t, gen_len), slab in slabs.items():
                parts: list[list[Request]] = []
                cur_part: list[Request] = []
                cur_b = 0
                for r in slab:
                    rb = r.prompts.shape[0]
                    if cur_part and cur_b + rb > key.batch_bucket:
                        parts.append(cur_part)
                        cur_part, cur_b = [], 0
                    cur_part.append(r)
                    cur_b += rb
                parts.append(cur_part)
                for part in parts:
                    prompts = jnp.concatenate([r.prompts for r in part],
                                              axis=0)
                    b = prompts.shape[0]
                    n0 = _jit_entries(_prefill) + _jit_entries(_decode_loop)
                    out, prefill_s, decode_s, tok_s = _timed_serve(
                        self.cfg, self.params, tree, prompts, gen_len)
                    cold = (_jit_entries(_prefill)
                            + _jit_entries(_decode_loop)) != n0
                    n_dispatch += 1
                    row = 0
                    for r in part:
                        rb = r.prompts.shape[0]
                        self._done[r.id] = Result(
                            id=r.id, tokens=out[row:row + rb], plan_key=key,
                            prefill_s=prefill_s, decode_s=decode_s,
                            tok_s=tok_s, cold=cold)
                        row += rb
                    served = {r.id for r in part}
                    self._pending = [r for r in self._pending
                                     if r.id not in served]
                    if not quiet:
                        print(f"[engine] group {key.describe()}: "
                              f"{len(part)} request(s) fused at "
                              f"{b}x{t}+{gen_len} ({tok_s:.1f} tok/s)")
            reports.append(GroupReport(
                key=key, request_ids=tuple(r.id for r in reqs),
                n_slabs=n_dispatch, total_batch=sum(r.prompts.shape[0]
                                                    for r in reqs)))
        return reports

    def retire(self, request_id: int | None = None) -> list[Result]:
        """Pop finished results (all of them, or one id). Unfinished ids are
        simply not returned — call ``step()`` first."""
        if request_id is not None:
            res = self._done.pop(request_id, None)
            return [res] if res is not None else []
        out = [self._done[k] for k in sorted(self._done)]
        self._done.clear()
        return out

    # -- live-training coherence -------------------------------------------

    def refresh(self, params, masks, mask_versions, *,
                donate: bool = True) -> dict[PlanKey, list[str]]:
        """Propagate a training job's update into every cached plan
        (incremental: only stacks whose version counter moved re-condense;
        the rest get values-only regathers — see ``Plan.refresh``). The
        engine's own (params, masks) references move to the new trees and
        the realized-stats cache is invalidated.

        The version counters are fetched ONCE (host-side cache: a later
        no-op refresh with the returned host ints does zero device syncs)
        and one shared ``export_cache`` dedupes the donated re-export
        across plan keys — a stack referenced by N cached plans condenses
        once per generation, every plan adopting the same leaf object."""
        self.params = params
        self.masks = masks or {}
        self._stats = None
        # draft trees alias the plans' value buffers BY IDENTITY and the
        # refresh donates those buffers — drop the drafts before any
        # donation executes; they re-derive lazily from the fresh trees
        self._draft_trees.clear()
        self._spec_estimates.clear()
        versions = PLAN._host_versions(mask_versions)
        self._mask_versions = versions
        cache: dict = {}
        return {key: plan.refresh(params, self.masks, versions,
                                  donate=donate, export_cache=cache)
                for key, plan in self._plans.items()}

    # -- streamed sync (repro.sync subscriber) ------------------------------

    def attach_subscriber(self, subscriber, *, donate: bool = True) -> None:
        """Attach a ``repro.sync.Subscriber``: pending deltas drain at
        paged-chunk boundaries (and at the top of every ``step``) and apply
        through the donated adoption path — published leaves overwrite the
        replica's existing buffers in place, zero weight-memory doubling.

        Only condensed-family fixed paths can subscribe: ``masked`` /
        ``structured`` / ``auto`` plans read the LIVE ``self.params`` at
        execution time, which a remote byte stream cannot keep current.
        ``donate=False`` is for engines sharing buffers with another live
        object (e.g. an in-process trainer)."""
        if self.path not in ("condensed", "condensed_over_active"):
            raise ValueError(
                f"attach_subscriber requires a condensed-family path; "
                f"path={self.path!r} reads live weights at execution time")
        if subscriber.generation is not None:
            self._check_sync_meta(subscriber.meta)
            # the engine is (assumed) built from the subscriber's current
            # state — clear the bootstrap's pending-change tracking so the
            # first drain only applies generations AFTER this one
            subscriber.consume_changes()
        # decouple the containers so sync writes never mutate a caller's
        # params tree in place (leaves still alias until first adoption)
        self.params = jax.tree_util.tree_map(lambda x: x, self.params)
        self._subscriber = subscriber
        self._sync_donate = bool(donate)
        self._sync_generation = subscriber.generation

    def _check_sync_meta(self, meta: dict) -> None:
        for field, mine in (("path", self.path),
                            ("values_dtype", self.values_dtype),
                            ("tp", self.tp)):
            theirs = meta.get(field, mine)
            if theirs != mine:
                raise ValueError(
                    f"sync stream {field}={theirs!r} does not match engine "
                    f"{field}={mine!r}; rebuild the engine to match the "
                    f"published layout")

    def _drain_sync(self) -> bool:
        """Poll the attached subscriber and apply any newly committed
        generations. Called at chunk boundaries — between dispatches, never
        mid-scan — so every in-flight decode chunk ran against ONE coherent
        generation. Returns True if state moved."""
        sub = self._subscriber
        if sub is None:
            return False
        sub.poll()
        if sub.generation is None or sub.generation == self._sync_generation:
            return False
        self._check_sync_meta(sub.meta)
        # sync adoption donates plan buffers the draft trees alias — drop
        # the drafts first; they re-derive from the adopted generation
        self._draft_trees.clear()
        self._spec_estimates.clear()
        changes = sub.consume_changes()
        if changes["snapshot"]:
            self.masks = sub.masks_tree()
        self._apply_sync_params(sub, changes)
        for plan in self._plans.values():
            self._apply_sync_to_plan(plan, sub, changes=changes)
        self._mask_versions = dict(sub.mask_versions)
        self._stats = None
        self._sync_generation = sub.generation
        return True

    def _apply_sync_params(self, sub, changes: dict) -> None:
        """Adopt changed dense (non-stack) param leaves — embeddings and
        norms keep training between topology updates and matter for token
        identity."""
        paths = (set(sub.params) if changes["snapshot"]
                 else changes["dense"])
        stack_names = {s.name for s in self.registry}
        for path in paths:
            if path in stack_names:
                continue
            parts = tuple(path.split("/"))
            try:
                old = REG.get_path(self.params, parts)
            except (KeyError, TypeError):
                old = None
            REG.set_path(self.params, parts,
                         F.adopt_array(sub.params[path], old,
                                       donate=self._sync_donate))

    def _leaf_from_wire(self, rec):
        """Build a device-side format leaf from a topology StackDelta."""
        cls = F.FORMATS[rec.format]
        kw = dict(rec.static)
        for f in cls._array_fields:
            arr = rec.arrays.get(f)
            kw[f] = jnp.asarray(arr) if arr is not None else None
        return cls(**kw)

    def _apply_sync_to_plan(self, plan, sub, *, changes: dict | None = None,
                            force: bool = False) -> None:
        """Adopt the subscriber's merged per-stack records into one plan.

        Same layout (class, statics, per-field shapes) -> in-place donated
        adoption of exactly the changed fields: the leaf keeps its avals,
        so every jitted program serving this plan stays a cache hit (no
        recompile of unchanged plan keys). A layout change (k or active-row
        count moved) rebuilds the leaf — that shape legitimately compiles
        fresh. ``force=True`` adopts every stack regardless of pending
        change tracking (used right after a lazily built plan exported from
        the engine's possibly stale local state)."""
        pending = (changes or {}).get("stacks", {})
        snapshot = bool((changes or {}).get("snapshot"))
        by_name = {s.name: s for s in self.registry}
        for name, rec in sub.leaves.items():
            s = by_name.get(name)
            if s is None:
                continue
            fields = pending.get(name, set())
            if not (force or snapshot or fields):
                continue
            old = REG.get_path(plan.serving_tree, s.path)
            cls = F.FORMATS[rec.format]
            same_layout = (
                type(old) is cls
                and all(getattr(old, f) == rec.static.get(f)
                        for f in cls._static_fields)
                and all((getattr(old, f) is None) == (f not in rec.arrays)
                        and (f not in rec.arrays
                             or (getattr(old, f).shape == rec.arrays[f].shape
                                 and getattr(old, f).dtype
                                 == rec.arrays[f].dtype))
                        for f in cls._array_fields))
            version_moved = (rec.mask_version
                             != plan.mask_versions.get(name))
            if same_layout:
                new_fields = {f: rec.arrays[f]
                              for f in (rec.arrays if (force or snapshot
                                                       or "__topology__"
                                                       in fields)
                                        else fields & set(rec.arrays))}
                if not new_fields:
                    continue
                leaf = old.adopt_arrays(new_fields,
                                        donate=self._sync_donate)
            else:
                leaf = self._leaf_from_wire(rec)
            REG.set_path(plan.serving_tree, s.path, leaf)
            topology = (not same_layout or version_moved or force
                        or snapshot or "__topology__" in fields)
            if topology:
                plan.export_calls += 1
                dec = plan.decisions[name]
                plan.decisions[name] = dataclasses.replace(
                    dec, representation=rec.format,
                    stats=COND.stats_from_leaf(leaf),
                    tp=int(rec.static.get("tp", 1)))
            else:
                plan.value_refreshes += 1
            plan.mask_versions[name] = rec.mask_version

    # -- calibration --------------------------------------------------------

    def autotune(self, batch_size: int, *, dtype=None,
                 reps: int = 3) -> dict[str, AT.TuneResult]:
        """Run the timed kernel block search for every condensed dispatch
        shape this engine's stacks produce at ``batch_size``'s bucket —
        keys derive from the formats' ``spec_tuning_key``, i.e. exactly what
        the Pallas wrappers look up at trace time. Tunes at the SERVING
        dtype (layers cast condensed values to the activation dtype; an f32
        tuning pass would never be looked up by a bf16 serving run)."""
        dtype = jnp.dtype(self.cfg.dtype if dtype is None else dtype)
        return AT.tune_registry(self.registry, self.stats(),
                                batch=batch_size, dtype=dtype, reps=reps,
                                values_dtype=self.values_dtype, tp=self.tp)


# ---------------------------------------------------------------------------
# allocation-free grouping (dry-run consumer)
# ---------------------------------------------------------------------------


def abstract_plan_key(cfg, registry, batch_size: int, *,
                      path: str = "auto",
                      profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
                      tp: int = 1) -> tuple[PlanKey, dict[str, str]]:
    """The plan key a request of ``batch_size`` would group under, computed
    from STATIC info only (target densities, no realized masks) — the
    grouping half of the engine, usable without allocating a model. Returns
    (key, per-stack representation dict) for ``plan.abstract_serving_tree``.
    ``tp`` prices the choice on a model mesh (collective included).
    """
    bucket = AT.batch_bucket(max(int(batch_size), 1))
    tp = max(int(tp), 1)
    if path != "auto":
        reps = {s.name: path for s in registry}
    else:
        reps = PLAN.plan_for_shape(cfg, registry, batch_size=bucket,
                                   profile=profile, tp=tp)
    key = PlanKey(batch_bucket=bucket,
                  formats=tuple((s.name, reps[s.name]) for s in registry),
                  tp=tp)
    return key, reps
