"""Programmatic serving engine: submit / step / retire over execution plans.

``ServingEngine`` is the API the serve CLI, the benchmarks and the examples
drive; it owns the pieces that used to be hand-wired per caller:

* **Admission** — ``submit(prompts, gen_len)`` queues a request (a batch of
  prompt streams) and returns its id.
* **Grouping by plan key** — pending requests are grouped by ``PlanKey``:
  the request's BATCH BUCKET (``autotune.BATCH_BUCKETS`` — the same buckets
  that key the kernel autotune cache, so a group's tuned blocks and its
  plan are calibrated for each other) crossed with the per-stack FORMAT
  signature the cost model picks at that bucket. One execution ``Plan``
  (serving pytree of ``repro.sparse.formats`` objects) is built lazily per
  key and shared by every request the key ever groups.
* **Execution** — ``step()`` runs each group through the jitted
  prefill + ``lax.scan`` greedy-decode programs (cache donated). Requests
  in a group with the same (prompt_len, gen_len) are CONCATENATED along the
  batch axis and decoded as one program dispatch — mixed-batch serving, the
  ROADMAP item this engine exists for. Greedy decode is batch-independent,
  so a request's tokens are identical whether it runs alone or fused into a
  group slab.
* **Retirement** — ``retire()`` pops finished ``Result``s (tokens +
  timings); ``refresh(params, masks, mask_versions)`` propagates a training
  job's incremental export into every cached plan.

``repro.launch.serve`` is a thin CLI over this module; the jitted
prefill/decode primitives and the ``generate``/``serve_once`` helpers live
here so every consumer shares one compile cache.
"""
from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.sparse import autotune as AT
from repro.sparse import condensed as COND
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG


# ---------------------------------------------------------------------------
# jitted execution primitives (module-level: one compile cache for all users)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("cfg",))
def _prefill(cfg, params, masks, batch, cache):
    # module-level jit (not a per-call lambda) so repeated serve calls on the
    # same cfg/shapes hit the compile cache — benchmark warm-up relies on it
    return M.prefill_step(cfg, params, masks, batch, cache)


@functools.partial(jax.jit, static_argnames=("cfg", "gen_len"),
                   donate_argnums=(3,))
def _decode_loop(cfg, params, masks, cache, first_tok, gen_len: int):
    """Greedy decode of ``gen_len`` tokens as one scanned program.

    first_tok: (B, 1) int32 — argmax of the prefill logits. The cache is
    donated: each scan step's cache update aliases the input buffers, so
    serving memory stays at one cache regardless of generation length.
    Returns (B, gen_len) generated tokens (first_tok first).
    """
    def body(carry, _):
        cur, cache = carry
        logits, cache = M.decode_step(cfg, params, masks, {"tokens": cur}, cache)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return (nxt, cache), cur[:, 0]

    (_, cache), toks = jax.lax.scan(body, (first_tok, cache), None,
                                    length=gen_len)
    return toks.T, cache


def _timed_serve(cfg, params, masks, prompts, gen_len: int):
    """One timed prefill+decode pass (the shared execution primitive).
    Returns (tokens (B, T+gen_len), prefill_s, decode_s, decode_tok_per_s)."""
    b, t = prompts.shape
    cache = M.init_cache(cfg, b, max_len=t + gen_len)

    t0 = time.perf_counter()
    logits, cache = _prefill(cfg, params, masks, {"tokens": prompts}, cache)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    first = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    toks, _ = _decode_loop(cfg, params, masks, cache, first, gen_len)
    toks.block_until_ready()
    t_decode = time.perf_counter() - t0

    tok_s = b * gen_len / max(t_decode, 1e-9)
    return jnp.concatenate([prompts, toks], axis=1), t_prefill, t_decode, tok_s


def serve_once(cfg, params, masks, prompts, gen_len: int, path_name: str,
               quiet: bool = False):
    """One timed prefill+decode pass. Returns (tokens, decode_tok_per_s)."""
    out, t_prefill, t_decode, tok_s = _timed_serve(cfg, params, masks,
                                                   prompts, gen_len)
    if not quiet:
        b, t = prompts.shape
        print(f"[serve:{path_name}] prefill {b}x{t} in {t_prefill:.3f}s | "
              f"decode {b}x{gen_len} in {t_decode:.3f}s ({tok_s:.1f} tok/s)")
    return out, tok_s


def generate(cfg, params, masks, prompts: jax.Array, gen_len: int):
    """prompts: (B, T) int32. Greedy decode. Returns (B, T+gen_len)."""
    out, _ = serve_once(cfg, params, masks, prompts, gen_len, "generate",
                        quiet=True)
    return out


# ---------------------------------------------------------------------------
# requests / plan keys / results
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanKey:
    """What makes two requests executable under one shared plan.

    ``batch_bucket`` — the autotune bucket the request's batch falls in
    (shared with the kernel tuning-cache keys, so the group's plan AND its
    tuned Pallas blocks come from the same calibration point).
    ``formats`` — the per-stack format signature the cost model picks at
    that bucket (registry order); a fixed ``path`` forces it uniform.
    """
    batch_bucket: int
    formats: tuple[tuple[str, str], ...]

    def describe(self) -> str:
        reps = {r for _, r in self.formats}
        rep = reps.pop() if len(reps) == 1 else "mixed"
        return f"b<={self.batch_bucket}/{rep}"


@dataclasses.dataclass
class Request:
    id: int
    prompts: jax.Array      # (B, T) int32
    gen_len: int


@dataclasses.dataclass
class Result:
    id: int
    tokens: jax.Array       # (B, T + gen_len) — prompt followed by greedy tokens
    plan_key: PlanKey
    prefill_s: float
    decode_s: float
    tok_s: float            # decode throughput of the slab this request ran in


@dataclasses.dataclass(frozen=True)
class GroupReport:
    """What one ``step()`` did for one plan-key group."""
    key: PlanKey
    request_ids: tuple[int, ...]
    n_slabs: int            # distinct (prompt_len, gen_len) program dispatches
    total_batch: int


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------


class ServingEngine:
    """Plan-keyed batch serving over a trained (params, masks) pair.

    >>> eng = ServingEngine(cfg, params, masks, registry, path="auto")
    >>> rid = eng.submit(prompts, gen_len=16)
    >>> eng.step()
    >>> [res] = eng.retire()

    ``path`` is any ``repro.sparse.plan.PATHS`` entry; ``"auto"`` lets each
    group's batch bucket pick per-stack formats by the cost model.
    ``profile`` prices those decisions (``HardwareProfile.measure()`` for a
    machine-calibrated one). Plans are built lazily per ``PlanKey`` at the
    BUCKET batch size and cached for the engine's lifetime; ``refresh``
    keeps them coherent with a live training job.
    """

    def __init__(self, cfg, params, masks, registry=None, *,
                 path: str = "auto",
                 profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
                 mask_versions: dict | None = None):
        if path not in PLAN.PATHS:
            raise ValueError(
                f"unknown serving path {path!r}; expected one of {PLAN.PATHS}")
        self.cfg = cfg
        self.params = params
        self.masks = masks or {}
        self.registry = list(REG.build_registry(cfg) if registry is None
                             else registry)
        self.path = path
        self.profile = profile
        self._mask_versions = mask_versions
        self._itemsize = jnp.dtype(cfg.param_dtype).itemsize
        self._stats: dict | None = None     # realized stats, computed once
        self._plans: dict[PlanKey, PLAN.Plan] = {}
        self._pending: list[Request] = []
        self._done: dict[int, Result] = {}
        self._next_id = 0

    # -- stats / keys -------------------------------------------------------

    def stats(self) -> dict:
        """Realized per-stack export stats (one fused host sync, cached)."""
        if self._stats is None:
            self._stats = COND.export_stats(self.registry, self.masks)
        return self._stats

    def plan_key(self, batch_size: int) -> PlanKey:
        """The key a request of ``batch_size`` streams groups under: its
        batch bucket x the per-stack format signature at that bucket."""
        bucket = AT.batch_bucket(max(int(batch_size), 1))
        if self.path != "auto":
            sig = tuple((s.name, self.path) for s in self.registry)
            return PlanKey(batch_bucket=bucket, formats=sig)
        stats = self.stats()
        sig = tuple(
            (s.name, PLAN.select_representation(
                s, batch_size=bucket, itemsize=self._itemsize,
                stats=stats[s.name], profile=self.profile).representation)
            for s in self.registry)
        return PlanKey(batch_bucket=bucket, formats=sig)

    def plan_for(self, key: PlanKey) -> PLAN.Plan:
        """The (lazily built, cached) execution plan serving ``key``."""
        plan = self._plans.get(key)
        if plan is None:
            plan = PLAN.build_plan(
                self.cfg, self.registry, self.params, self.masks,
                batch_size=key.batch_bucket, path=self.path,
                mask_versions=self._mask_versions, profile=self.profile)
            self._plans[key] = plan
        return plan

    def serving_tree_for(self, key: PlanKey):
        """The masks-slot pytree a group executes with. The all-masked fixed
        path serves the training-layout masks directly (identity — no
        export, the pre-engine ``--path masked`` fast path)."""
        if self.path == "masked":
            return self.masks
        return self.plan_for(key).serving_tree

    # -- request lifecycle --------------------------------------------------

    def submit(self, prompts, gen_len: int) -> int:
        """Admit a request: ``prompts`` (B, T) int32, decode ``gen_len``
        greedy tokens per stream. Returns the request id."""
        prompts = jnp.asarray(prompts)
        if prompts.ndim != 2:
            raise ValueError(f"prompts must be (batch, prompt_len); "
                             f"got shape {prompts.shape}")
        if gen_len < 1:
            raise ValueError("gen_len must be >= 1")
        rid = self._next_id
        self._next_id += 1
        self._pending.append(Request(id=rid, prompts=prompts,
                                     gen_len=int(gen_len)))
        return rid

    def pending_groups(self) -> dict[PlanKey, list[int]]:
        """Predicted grouping of the pending requests (no execution)."""
        groups: dict[PlanKey, list[int]] = {}
        for req in self._pending:
            groups.setdefault(self.plan_key(req.prompts.shape[0]),
                              []).append(req.id)
        return groups

    def step(self, quiet: bool = True) -> list[GroupReport]:
        """Serve every pending request, one plan-key group at a time.

        Within a group, requests sharing (prompt_len, gen_len) are fused
        into one batch slab and decoded by a single jitted program dispatch;
        slabs with different shapes reuse the group's plan but compile their
        own program (shape-polymorphic fusion — padding slabs up to the
        bucket is the continuous-batching follow-up). Results land in the
        retire queue.
        """
        groups: dict[PlanKey, list[Request]] = {}
        for req in self._pending:
            groups.setdefault(self.plan_key(req.prompts.shape[0]),
                              []).append(req)

        reports = []
        for key, reqs in groups.items():
            # requests stay in the pending queue until their slab has
            # actually executed: an exception mid-step (plan build, compile,
            # OOM) must not silently drop queued work — unexecuted requests
            # remain pending for a later step()
            tree = self.serving_tree_for(key)
            slabs: dict[tuple[int, int], list[Request]] = {}
            for req in reqs:
                slabs.setdefault((req.prompts.shape[1], req.gen_len),
                                 []).append(req)
            for (t, gen_len), slab in slabs.items():
                prompts = jnp.concatenate([r.prompts for r in slab], axis=0)
                b = prompts.shape[0]
                out, prefill_s, decode_s, tok_s = _timed_serve(
                    self.cfg, self.params, tree, prompts, gen_len)
                row = 0
                for r in slab:
                    rb = r.prompts.shape[0]
                    self._done[r.id] = Result(
                        id=r.id, tokens=out[row:row + rb], plan_key=key,
                        prefill_s=prefill_s, decode_s=decode_s, tok_s=tok_s)
                    row += rb
                served = {r.id for r in slab}
                self._pending = [r for r in self._pending
                                 if r.id not in served]
                if not quiet:
                    print(f"[engine] group {key.describe()}: "
                          f"{len(slab)} request(s) fused at {b}x{t}+{gen_len} "
                          f"({tok_s:.1f} tok/s)")
            reports.append(GroupReport(
                key=key, request_ids=tuple(r.id for r in reqs),
                n_slabs=len(slabs), total_batch=sum(r.prompts.shape[0]
                                                    for r in reqs)))
        return reports

    def retire(self, request_id: int | None = None) -> list[Result]:
        """Pop finished results (all of them, or one id). Unfinished ids are
        simply not returned — call ``step()`` first."""
        if request_id is not None:
            res = self._done.pop(request_id, None)
            return [res] if res is not None else []
        out = [self._done[k] for k in sorted(self._done)]
        self._done.clear()
        return out

    # -- live-training coherence -------------------------------------------

    def refresh(self, params, masks, mask_versions, *,
                donate: bool = True) -> dict[PlanKey, list[str]]:
        """Propagate a training job's update into every cached plan
        (incremental: only stacks whose version counter moved re-condense;
        the rest get values-only regathers — see ``Plan.refresh``). The
        engine's own (params, masks) references move to the new trees and
        the realized-stats cache is invalidated."""
        self.params = params
        self.masks = masks or {}
        self._stats = None
        self._mask_versions = mask_versions
        return {key: plan.refresh(params, self.masks, mask_versions,
                                  donate=donate)
                for key, plan in self._plans.items()}

    # -- calibration --------------------------------------------------------

    def autotune(self, batch_size: int, *, dtype=None,
                 reps: int = 3) -> dict[str, AT.TuneResult]:
        """Run the timed kernel block search for every condensed dispatch
        shape this engine's stacks produce at ``batch_size``'s bucket —
        keys derive from the formats' ``spec_tuning_key``, i.e. exactly what
        the Pallas wrappers look up at trace time. Tunes at the SERVING
        dtype (layers cast condensed values to the activation dtype; an f32
        tuning pass would never be looked up by a bf16 serving run)."""
        dtype = jnp.dtype(self.cfg.dtype if dtype is None else dtype)
        return AT.tune_registry(self.registry, self.stats(),
                                batch=batch_size, dtype=dtype, reps=reps)


# ---------------------------------------------------------------------------
# allocation-free grouping (dry-run consumer)
# ---------------------------------------------------------------------------


def abstract_plan_key(cfg, registry, batch_size: int, *,
                      path: str = "auto",
                      profile: PLAN.HardwareProfile = PLAN.DEFAULT_PROFILE,
                      ) -> tuple[PlanKey, dict[str, str]]:
    """The plan key a request of ``batch_size`` would group under, computed
    from STATIC info only (target densities, no realized masks) — the
    grouping half of the engine, usable without allocating a model. Returns
    (key, per-stack representation dict) for ``plan.abstract_serving_tree``.
    """
    bucket = AT.batch_bucket(max(int(batch_size), 1))
    if path != "auto":
        reps = {s.name: path for s in registry}
    else:
        reps = PLAN.plan_for_shape(cfg, registry, batch_size=bucket,
                                   profile=profile)
    key = PlanKey(batch_bucket=bucket,
                  formats=tuple((s.name, reps[s.name]) for s in registry))
    return key, reps
