"""Launch layer: production meshes, sharding rules, dry-run, train/serve CLIs."""
