"""Learning-rate schedules (traceable in step)."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  min_lr: float = 0.0):
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
        cos = min_lr + 0.5 * (base_lr - min_lr) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)
    return lr


def warmup_step(base_lr: float, warmup_steps: int, boundaries: tuple, factor: float = 0.1):
    """Step decay (paper's ResNet recipe: /10 at epochs 30/70/90)."""
    def lr(step):
        s = jnp.asarray(step, jnp.float32)
        warm = base_lr * s / jnp.maximum(warmup_steps, 1)
        mult = jnp.ones(())
        for b in boundaries:
            mult = mult * jnp.where(s >= b, factor, 1.0)
        return jnp.where(s < warmup_steps, warm, base_lr * mult)
    return lr
