"""Sparse-aware optimizers (functional, optax-style but self-contained).

Each optimizer is (init_fn, update_fn):

  state = init(params)
  new_params, new_state = update(params, grads, state, lr, masks=None)

Sparse-awareness: when a ``masks`` pytree is given (paths mirroring params;
missing paths = dense), the *gradient applied to the weight* is masked, while
the incoming ``grads`` stay dense (the trainer reuses them for the RigL/SRigL
grow criterion). Optimizer moments are masked too, so pruned slots carry no
stale momentum — the RigL reference behaviour (regrown weights restart from
zero weight, zero momentum).

``adafactor`` (factored second moment, optional momentumless) is what the
100B+ configs use: at 1T parameters unfactored Adam moments cannot fit HBM
(DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _tree_get(masks: dict | None, path: tuple):
    if masks is None:
        return None
    node = masks
    for p in path:
        if not isinstance(node, dict) or p not in node:
            return None
        node = node[p]
    return node


def _map_with_path(fn, params, *rest):
    """tree_map that also passes the dict-path of each leaf."""
    def rec(path, p, *r):
        if isinstance(p, dict):
            return {k: rec(path + (k,), p[k], *[x[k] for x in r]) for k in p}
        return fn(path, p, *r)
    return rec((), params, *rest)


def _masked(g, mask):
    return g * mask.astype(g.dtype) if mask is not None else g


# ---------------------------------------------------------------------------
# SGD + momentum (paper's CNN recipe)
# ---------------------------------------------------------------------------

def sgd_momentum(momentum: float = 0.9, weight_decay: float = 0.0):
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(params, grads, state, lr, masks=None, step=None):
        def upd(path, p, g, mu):
            m = _tree_get(masks, path)
            g = _masked(g.astype(jnp.float32), m)
            if weight_decay:
                g = g + weight_decay * _masked(p.astype(jnp.float32), m)
            mu_new = momentum * mu + g
            if m is not None:
                mu_new = _masked(mu_new, m)
            return (p.astype(jnp.float32) - lr * mu_new).astype(p.dtype), mu_new

        out = _map_with_path(upd, params, grads, state["mu"])
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"mu": new_mu}

    return init, update


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr, masks=None, step=None):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(path, p, g, mu, nu):
            m = _tree_get(masks, path)
            g = _masked(g.astype(jnp.float32), m)
            mu_new = b1 * mu + (1 - b1) * g
            nu_new = b2 * nu + (1 - b2) * g * g
            if m is not None:
                mu_new, nu_new = _masked(mu_new, m), _masked(nu_new, m)
            upd_ = (mu_new / bc1) / (jnp.sqrt(nu_new / bc2) + eps)
            if weight_decay:
                upd_ = upd_ + weight_decay * _masked(p.astype(jnp.float32), m)
            return (p.astype(jnp.float32) - lr * upd_).astype(p.dtype), mu_new, nu_new

        out = _map_with_path(upd, params, grads, state["mu"], state["nu"])
        pick = lambda i: jax.tree.map(lambda t: t[i], out, is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), {"mu": pick(1), "nu": pick(2), "count": c}

    return init, update


# ---------------------------------------------------------------------------
# Adafactor (factored second moment; for the 100B-1T configs)
# ---------------------------------------------------------------------------

def adafactor(eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8, weight_decay: float = 0.0):
    """Momentum-less Adafactor (Shazeer & Stern 2018) with factored 2nd moment
    for tensors of rank >= 2 (factored over the last two axes)."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def st(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(st, params,
                                  is_leaf=lambda x: not isinstance(x, dict)),
                "count": jnp.zeros((), jnp.int32)}

    def update(params, grads, state, lr, masks=None, step=None):
        c = state["count"] + 1
        rho = 1.0 - c.astype(jnp.float32) ** -decay

        def upd(path, p, g, v):
            m = _tree_get(masks, path)
            g = _masked(g.astype(jnp.float32), m)
            g2 = g * g + eps
            if _factored(p):
                vr = rho * v["vr"] + (1 - rho) * jnp.mean(g2, axis=-1)
                vc = rho * v["vc"] + (1 - rho) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps))
                u = g / jnp.maximum(denom, eps)
                new_v = {"vr": vr, "vc": vc}
            else:
                vv = rho * v["v"] + (1 - rho) * g2
                u = g / jnp.sqrt(jnp.maximum(vv, eps))
                new_v = {"v": vv}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * _masked(p.astype(jnp.float32), m)
            if m is not None:
                u = _masked(u, m)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_v

        out = _map_with_path(upd, params, grads, state["v"])
        new_params = _map_with_path(lambda path, t: t[0], out)
        new_v = _map_with_path(lambda path, t: t[1], out)
        return new_params, {"v": new_v, "count": c}

    return init, update


def make_optimizer(name: str, **kw):
    if name == "sgdm":
        return sgd_momentum(**kw)
    if name == "adamw":
        return adamw(**kw)
    if name == "adafactor":
        return adafactor(**kw)
    raise ValueError(name)
