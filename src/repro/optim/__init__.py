"""Optimizers, LR schedules, gradient compression."""
from repro.optim.optimizers import (  # noqa: F401
    adafactor,
    adamw,
    make_optimizer,
    sgd_momentum,
)
from repro.optim.schedules import warmup_cosine, warmup_step  # noqa: F401
