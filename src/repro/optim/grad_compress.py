"""Cross-pod gradient compression with error feedback (beyond-paper, §Perf).

At 2+ pods the data-parallel gradient all-reduce crosses the DCN, which is
>10x slower per byte than ICI. Compressing gradients to bf16 (or int8 with
per-tensor scale) before the reduction halves (or quarters) cross-pod bytes;
the quantization error is fed back into the next step's gradient (error
feedback / EF-SGD) so convergence is preserved.

Usage in the trainer: grads are compressed *before* they leave the backward
pass via jax.lax.psum-equivalent (here: before the optimizer consumes them,
with XLA's all-reduce operating on the compressed dtype).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> dict:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_bf16(grads, ef_state):
    """Round grads+error to bf16; return (compressed, new_error)."""
    def comp(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        c = g32.astype(jnp.bfloat16)
        return c, (g32 - c.astype(jnp.float32)).astype(jnp.bfloat16)
    out = jax.tree.map(comp, grads, ef_state)
    comp_t = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err_t = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return comp_t, err_t


def compress_int8(grads, ef_state):
    """Per-tensor symmetric int8 quantization with error feedback."""
    def comp(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return (q, scale), (g32 - deq).astype(jnp.bfloat16)
    out = jax.tree.map(comp, grads, ef_state)
    comp_t = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    err_t = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return comp_t, err_t


def decompress_int8(comp):
    return jax.tree.map(lambda t: t[0].astype(jnp.float32) * t[1],
                        comp, is_leaf=lambda t: isinstance(t, tuple))
