"""Deterministic, restart-safe synthetic data pipeline.

Production shape without external deps: per-host sharded batches, seeded by
(run_seed, step) so a restarted job regenerates *exactly* the batch it would
have seen — checkpoint/restart reproducibility without persisting any data
cursor beyond the step counter. A background prefetch thread keeps ``depth``
batches ahead of the training loop (overlap host data work with device step).

The synthetic LM stream is a order-2 Markov chain over the vocab (not iid
uniform) so cross-entropy actually *decreases* during the example runs —
needed for the paper-faithfulness accuracy proxies in benchmarks/accuracy.py.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    """Markov-chain token stream with per-(seed, step) determinism."""

    vocab_size: int
    seq_len: int
    batch_size: int          # per-host batch
    seed: int = 0
    n_codebooks: int = 0     # audio (musicgen) stream
    d_model: int = 0         # for frontend-embedding stubs (vlm/vit)
    family: str = "dense"

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # sparse-ish row-stochastic transition matrix (each token has ~8 successors)
        succ = min(8, v)
        self._succ_idx = rng.integers(0, v, size=(v, succ))
        self._succ_p = rng.dirichlet(np.ones(succ), size=v)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed * 1_000_003 + step) % (2**63))
        b, t, v = self.batch_size, self.seq_len, self.vocab_size

        def stream(n):
            toks = np.empty((n, t + 1), np.int32)
            toks[:, 0] = rng.integers(0, v, size=n)
            for i in range(t):
                cur = toks[:, i]
                choice = (rng.random(n)[:, None] < np.cumsum(self._succ_p[cur], -1)).argmax(-1)
                toks[:, i + 1] = self._succ_idx[cur, choice]
            return toks

        if self.family == "audio":
            k = self.n_codebooks
            s = stream(b * k).reshape(b, k, t + 1)
            batch = {"tokens": s[..., :-1], "targets": s[..., 1:]}
        elif self.family == "vit":
            batch = {
                "frontend_embeds": rng.standard_normal((b, t, self.d_model)).astype(np.float32),
                "labels": rng.integers(0, max(self.vocab_size, 2), size=b).astype(np.int32),
            }
        else:
            s = stream(b)
            batch = {"tokens": s[:, :-1], "targets": s[:, 1:]}
            if self.family == "vlm":
                batch["frontend_embeds"] = (
                    rng.standard_normal((b, t, self.d_model)).astype(np.float32) * 0.02)
                pos = np.broadcast_to(np.arange(t, dtype=np.int32)[None], (b, t))
                batch["mrope_positions"] = np.stack([pos, pos, pos])
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of ``depth`` batches."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        for item in self._it:
            if self._stop.is_set():
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass


# ---------------------------------------------------------------------------
# shape specs (used by launch/dryrun.py — ShapeDtypeStructs, no allocation)
# ---------------------------------------------------------------------------

def make_batch_spec(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of (cfg, shape)."""
    b, t = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f = jnp.dtype(cfg.dtype)
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        tt = 1
        if cfg.family == "audio":
            return {"tokens": sds((b, cfg.n_codebooks, tt), i32)}
        batch = {"tokens": sds((b, tt), i32)}
        if cfg.family == "vlm":
            batch["mrope_positions"] = sds((3, b, tt), i32)
        return batch
    if cfg.family == "audio":
        return {"tokens": sds((b, cfg.n_codebooks, t), i32),
                "targets": sds((b, cfg.n_codebooks, t), i32)}
    if cfg.family == "vit":
        return {"frontend_embeds": sds((b, t, cfg.d_model), f),
                "labels": sds((b,), i32)}
    batch = {"tokens": sds((b, t), i32), "targets": sds((b, t), i32)}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = sds((b, t, cfg.d_model), f)
        batch["mrope_positions"] = sds((3, b, t), i32)
    return batch


def make_train_batch(cfg, key: jax.Array, batch_size: int, seq_len: int) -> dict:
    """Random device-resident batch (tests / examples)."""
    if cfg.family == "audio":
        toks = jax.random.randint(key, (batch_size, cfg.n_codebooks, seq_len + 1),
                                  0, cfg.vocab_size)
        return {"tokens": toks[..., :-1], "targets": toks[..., 1:]}
    if cfg.family == "vit":
        return {"frontend_embeds": jax.random.normal(key, (batch_size, seq_len, cfg.d_model)),
                "labels": jax.random.randint(key, (batch_size,), 0, max(cfg.n_classes, 2))}
    toks = jax.random.randint(key, (batch_size, seq_len + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
    if cfg.family == "vlm":
        batch["frontend_embeds"] = jax.random.normal(
            key, (batch_size, seq_len, cfg.d_model)) * 0.02
        p = jnp.broadcast_to(jnp.arange(seq_len)[None], (batch_size, seq_len))
        batch["mrope_positions"] = jnp.stack([p, p, p])
    return batch
