"""Data pipeline: deterministic synthetic LM streams + modality stubs."""
from repro.data.pipeline import (  # noqa: F401
    SyntheticLM,
    make_batch_spec,
    make_train_batch,
)
