"""JAX version-compat layer: one import site for every mesh/layout API that
moved between JAX 0.4.x and current.

The repo targets the newest mesh APIs (`jax.sharding.get_abstract_mesh`,
`jax.set_mesh`, `jax.sharding.AxisType`, `jax.experimental.layout.Format`) but
must run on the 0.4.x series baked into CPU test containers. Every module that
touches mesh state imports these shims instead of jax directly:

  get_abstract_mesh()   -> AbstractMesh | None  (None == "not under a mesh")
  use_mesh(mesh)        -> context manager entering BOTH the physical-mesh
                           resource env and the abstract-mesh tracing context
                           (on 0.4.x these are two separate thread-locals; on
                           current JAX it is jax.set_mesh)
  make_mesh(shape, axes)-> jax.make_mesh with axis_types=Auto when the
                           installed version supports explicit axis types
  Format / DeviceLayout -> jax.experimental.layout.{Format, Layout} on current
                           JAX, {Layout, DeviceLocalLayout} on 0.4.x

The shims are resolved at import time (cheap getattr probes, no version
string parsing) so behaviour under a given JAX install is deterministic.
"""
from __future__ import annotations

import contextlib

import jax

# Stable across every supported version — re-exported so sharding code has a
# single compat import site.
from jax.sharding import NamedSharding, PartitionSpec  # noqa: F401


def _mesh_internals():
    from jax._src import mesh as mesh_src
    return mesh_src


# ---------------------------------------------------------------------------
# abstract mesh
# ---------------------------------------------------------------------------

def get_abstract_mesh():
    """The abstract mesh of the current tracing context, or None.

    Normalizes the cross-version zoo of "no mesh" sentinels (missing symbol,
    ``None``, empty tuple, ``AbstractMesh(empty=True)``) to a plain ``None`` so
    callers can write ``if compat.get_abstract_mesh() is None``.
    """
    public = getattr(jax.sharding, "get_abstract_mesh", None)
    if public is not None:
        mesh = public()
    else:
        try:
            mesh = _mesh_internals().get_abstract_mesh()
        except Exception:  # noqa: BLE001 — any internals drift means "no mesh"
            return None
    if mesh is None or isinstance(mesh, tuple):
        return None
    if getattr(mesh, "empty", False):
        return None
    return mesh


@contextlib.contextmanager
def use_mesh(mesh):
    """Enter ``mesh`` for both execution and tracing, on any JAX version.

    Equivalent to ``with jax.set_mesh(mesh):`` on current JAX. On 0.4.x the
    physical resource env (consumed by ``with_sharding_constraint`` given a
    bare PartitionSpec) and the abstract mesh (consumed by shard_hint during
    tracing, and part of the jit cache key) are separate thread-locals; this
    enters both.
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        with set_mesh(mesh):
            yield mesh
        return
    sharding_use = getattr(jax.sharding, "use_mesh", None)
    if sharding_use is not None:
        with sharding_use(mesh):
            yield mesh
        return
    mesh_src = _mesh_internals()
    with contextlib.ExitStack() as stack:
        stack.enter_context(mesh)  # physical resource env
        abstract = getattr(mesh, "abstract_mesh", None)
        if abstract is not None and hasattr(mesh_src, "set_abstract_mesh"):
            stack.enter_context(mesh_src.set_abstract_mesh(abstract))
        yield mesh


# Drop-in for call sites written against the current-JAX name.
set_mesh = use_mesh


# ---------------------------------------------------------------------------
# mesh construction
# ---------------------------------------------------------------------------

def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh, requesting Auto axis types where the API exists."""
    kwargs = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=(axis_type.Auto,) * len(axis_names),
                                 **kwargs)
        except TypeError:  # version with AxisType but older make_mesh signature
            pass
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


# ---------------------------------------------------------------------------
# compiled-program introspection
# ---------------------------------------------------------------------------

def cost_analysis(compiled) -> dict:
    """Normalized ``compiled.cost_analysis()``: current JAX returns a flat
    dict, 0.4.x returns a one-element list of dicts (one per computation)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost) if cost else {}


# ---------------------------------------------------------------------------
# layout / Format
# ---------------------------------------------------------------------------

try:  # current JAX: Format wraps (DeviceLocalLayout-like, Sharding)
    from jax.experimental.layout import Format  # type: ignore
    try:
        from jax.experimental.layout import Layout as DeviceLayout  # type: ignore
    except ImportError:  # pragma: no cover
        DeviceLayout = None
except ImportError:
    try:  # 0.4.x: Layout plays Format's role; DeviceLocalLayout the inner one
        from jax.experimental.layout import Layout as Format  # type: ignore
        from jax.experimental.layout import DeviceLocalLayout as DeviceLayout  # type: ignore
    except ImportError:  # pragma: no cover — layouts unavailable entirely
        Format = None
        DeviceLayout = None

HAS_FORMAT = Format is not None


def default_format():
    """A no-constraint layout value accepted by jit's in_shardings/out_layouts
    slots on every supported version (None == "compiler picks")."""
    return None
