"""Output-norm variance theory (paper Appendix A/B, Eqs. 1-3) + Monte-Carlo check.

For a ReLU layer z = sqrt(2/k) (W ⊙ I)(ξ ⊙ u) with n neurons and mean fan-in k,
E[||z||^2 / ||u||^2] = 1 and the variance depends on the sparsity *structure*:

  Bernoulli            Var = (5n - 8 + 18 n/k) / (n (n+2))                 (1)
  Constant-per-layer   Var = ((n^2+7n-8) C_{n,k} + 18 n/k - n^2 - 2n)
                             / (n (n+2)),  C_{n,k} = (n - 1/k)/(n - 1/n)   (2)
  Constant fan-in      Var = Bernoulli - 3 (n-k) / (k n (n+2))             (3)

NOTE: the paper's *main-text* Eqs. (1)-(2) print the third term as ``18 k/n``,
but the Appendix B derivations (Props. B.4-B.6) and our Monte-Carlo simulation
both give ``18 n/k`` — we implement the appendix (correct) version; the
simulation test in tests/test_theory.py pins this down.

Constant fan-in always has the *smallest* variance — the paper's theoretical
motivation for SRigL. The simulator draws the three index-matrix ensembles and
estimates Var(||z||^2) empirically (Fig. 1b).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def var_bernoulli(n: int, k: int) -> float:
    return (5 * n - 8 + 18 * n / k) / (n * (n + 2))


def c_nk(n: int, k: int) -> float:
    return (n - 1 / k) / (n - 1 / n)


def var_const_per_layer(n: int, k: int) -> float:
    return ((n**2 + 7 * n - 8) * c_nk(n, k) + 18 * n / k - n**2 - 2 * n) / (n * (n + 2))


def var_const_fan_in(n: int, k: int) -> float:
    return var_bernoulli(n, k) - 3 * (n - k) / (k * n * (n + 2))


# ---------------------------------------------------------------------------
# Monte-Carlo simulation
# ---------------------------------------------------------------------------

def _sample_index_matrix(key: jax.Array, n: int, k: int, kind: str) -> jax.Array:
    if kind == "bernoulli":
        return jax.random.bernoulli(key, k / n, (n, n))
    if kind == "const_per_layer":
        flat = jnp.zeros((n * n,), bool).at[: k * n].set(True)
        return jax.random.permutation(key, flat).reshape(n, n)
    if kind == "const_fan_in":
        # exactly k ones per row, rows independent
        scores = jax.random.uniform(key, (n, n))
        ranks = jnp.argsort(jnp.argsort(-scores, axis=1), axis=1)
        return ranks < k
    raise ValueError(kind)


def simulate_output_norm_var(
    key: jax.Array, n: int, k: int, kind: str, n_samples: int = 2000
) -> float:
    """Empirical Var(||z||^2) for the given sparsity ensemble."""

    def one(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        u = jax.random.normal(k1, (n,))
        u = u / jnp.linalg.norm(u)               # uniform on the unit sphere
        xi = jax.random.bernoulli(k2, 0.5, (n,))  # ReLU-style half-activity
        ind = _sample_index_matrix(k3, n, k, kind)
        w = jax.random.normal(k4, (n, n))
        z = jnp.sqrt(2.0 / k) * (w * ind) @ (xi * u)
        return jnp.sum(z * z)

    norms = jax.vmap(one)(jax.random.split(key, n_samples))
    return float(jnp.var(norms))


def theory_table(n: int, ks: list[int]) -> "np.ndarray":
    """Rows: k; cols: [bernoulli, const_per_layer, const_fan_in] variances."""
    return np.array(
        [[var_bernoulli(n, k), var_const_per_layer(n, k), var_const_fan_in(n, k)] for k in ks]
    )
