"""Per-layer sparsity distributions (ERK, uniform) — Evci et al. 2021 / Mocanu et al. 2018.

Given a global target sparsity S and the set of sparsifiable layers, assign each
layer a density so that the *parameter-weighted* mean density equals (1 - S).

ERK (Erdos-Renyi-Kernel) for a linear layer of shape (d_in, d_out) uses the raw
Erdos-Renyi score (d_in + d_out) / (d_in * d_out); layers whose score would push
density above 1.0 are clamped dense and the remaining budget is re-solved — the
standard iterative-capping scheme from the RigL reference implementation.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """Static description of one sparsifiable weight tensor."""

    name: str
    d_in: int   # fan-in of each output unit (kernel dims folded in for convs)
    d_out: int  # number of output units (neurons / filters / expert rows)
    n_replicas: int = 1  # e.g. experts sharing one logical layer shape

    @property
    def n_params(self) -> int:
        return self.d_in * self.d_out * self.n_replicas

    @property
    def er_score(self) -> float:
        return (self.d_in + self.d_out) / (self.d_in * self.d_out)


def uniform_densities(layers: Sequence[LayerShape], sparsity: float) -> dict[str, float]:
    """Every layer gets the same density 1 - sparsity."""
    _check_sparsity(sparsity)
    return {l.name: 1.0 - sparsity for l in layers}


def erk_densities(layers: Sequence[LayerShape], sparsity: float) -> dict[str, float]:
    """ERK densities: density_l = eps * er_score_l, eps solved for the global budget.

    Iteratively clamps layers that would exceed density 1.0.
    """
    _check_sparsity(sparsity)
    if not layers:
        return {}
    total_params = sum(l.n_params for l in layers)
    budget = (1.0 - sparsity) * total_params

    dense_set: set[str] = set()
    while True:
        # Params already spent on clamped-dense layers.
        dense_params = sum(l.n_params for l in layers if l.name in dense_set)
        free_layers = [l for l in layers if l.name not in dense_set]
        if not free_layers:
            break
        denom = sum(l.er_score * l.n_params for l in free_layers)
        eps = (budget - dense_params) / max(denom, 1e-12)
        overflow = [l for l in free_layers if eps * l.er_score > 1.0]
        if not overflow:
            break
        dense_set.update(l.name for l in overflow)

    out: dict[str, float] = {}
    for l in layers:
        if l.name in dense_set:
            out[l.name] = 1.0
        else:
            out[l.name] = max(min(eps * l.er_score, 1.0), 0.0)
    return out


def realized_sparsity(layers: Sequence[LayerShape], densities: Mapping[str, float]) -> float:
    """Parameter-weighted global sparsity actually realized by ``densities``."""
    total = sum(l.n_params for l in layers)
    nnz = sum(densities[l.name] * l.n_params for l in layers)
    return 1.0 - nnz / max(total, 1)


def fan_in_from_density(d_in: int, density: float) -> int:
    """Constant fan-in k for a layer: at least 1 non-zero per neuron."""
    return max(1, round(density * d_in))


def _check_sparsity(sparsity: float) -> None:
    if not 0.0 <= sparsity < 1.0:
        raise ValueError(f"sparsity must be in [0, 1), got {sparsity}")
