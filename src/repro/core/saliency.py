"""Shared saliency helpers for DST updates.

All helpers are shape-static and traceable: counts like "top K" with a *traced*
K are realized via rank comparisons (double argsort) instead of ``lax.top_k``,
which requires a static k. Ranks are exact, so selected-set sizes are exact even
with ties.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG = jnp.float32(-jnp.inf)


def descending_ranks(x: jax.Array, axis: int | None = None) -> jax.Array:
    """Rank of each element in descending order along ``axis`` (0 = largest).

    axis=None ranks over the flattened array (returned in original shape).
    """
    if axis is None:
        flat = x.ravel()
        order = jnp.argsort(-flat, stable=True)
        ranks = jnp.empty_like(order).at[order].set(jnp.arange(flat.shape[0]))
        return ranks.reshape(x.shape)
    order = jnp.argsort(-x, axis=axis, stable=True)
    ar = jnp.arange(x.shape[axis])
    ar = ar.reshape([-1 if i == axis % x.ndim else 1 for i in range(x.ndim)])
    ranks = jnp.empty_like(order)
    ranks = jnp.put_along_axis(
        ranks, order, jnp.broadcast_to(ar, x.shape), axis=axis, inplace=False
    )
    return ranks


def prune_survivors(weight: jax.Array, mask: jax.Array, n_prune) -> jax.Array:
    """Layer-wise magnitude prune: drop the ``n_prune`` smallest-|w| active weights.

    Returns the survivor mask (bool, same shape). ``n_prune`` may be traced.
    """
    mag = jnp.where(mask, jnp.abs(weight), NEG)
    ranks = descending_ranks(mag)  # active weights occupy ranks [0, A)
    n_active = jnp.sum(mask)
    return mask & (ranks < (n_active - n_prune))


def top_k_candidates(score: jax.Array, candidates: jax.Array, n_grow) -> jax.Array:
    """Layer-wise top-``n_grow`` of ``score`` restricted to ``candidates`` (bool)."""
    s = jnp.where(candidates, score, NEG)
    ranks = descending_ranks(s)
    return candidates & (ranks < n_grow)


def topk_threshold(values: jax.Array, candidates: jax.Array, k,
                   iters: int = 30) -> jax.Array:
    """Scalar threshold t with count(values > t & candidates) ~= k.

    Sharding-friendly replacement for a global flattened top-k: a bisection
    over the value range using only compare+reduce (no sort, no gather, O(1)
    temp memory, fully SPMD-partitionable). Realized counts match k up to
    floating-point quantile resolution (2^-iters of the value range); the
    per-column exact selection in srigl_update restores exact counts.
    """
    vmax = jnp.max(jnp.where(candidates, values, 0.0))
    lo = jnp.zeros((), values.dtype)
    hi = vmax + 1e-6

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        c = jnp.sum((values > mid) & candidates)
        return jnp.where(c > k, mid, lo), jnp.where(c > k, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return lo


def select_topk_threshold(values: jax.Array, candidates: jax.Array, k,
                          iters: int = 30) -> jax.Array:
    """Bool mask of ~k largest ``values`` among ``candidates`` (thresholded)."""
    t = topk_threshold(values, candidates, k, iters)
    return candidates & (values > t)


def normalized(x: jax.Array, where: jax.Array | None = None) -> jax.Array:
    """|x| scaled into [0, 1] (by the max over ``where`` if given)."""
    a = jnp.abs(x)
    if where is not None:
        m = jnp.max(jnp.where(where, a, 0.0))
    else:
        m = jnp.max(a)
    return a / (m + 1e-12)
