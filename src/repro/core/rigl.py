"""RigL baseline (Evci et al. 2021) — unstructured sparse-to-sparse DST.

Prunes the K smallest-magnitude active weights per layer and regrows the K
largest-|gradient| inactive positions. No structural constraint. Implemented
with the same rank machinery as SRigL so the two are directly comparable.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import saliency


@dataclasses.dataclass(frozen=True)
class RigLSpec:
    name: str
    d_in: int
    d_out: int
    density: float

    @property
    def target_nnz(self) -> int:
        return max(1, round(self.density * self.d_in * self.d_out))


class RigLState(NamedTuple):
    mask: jax.Array  # bool (d_in, d_out)


def init_layer_state(key: jax.Array, spec: RigLSpec) -> RigLState:
    from repro.core import topology

    return RigLState(
        mask=topology.random_unstructured_mask(key, spec.d_in, spec.d_out, spec.target_nnz)
    )


def rigl_update(
    spec: RigLSpec,
    weight: jax.Array,
    dense_grad: jax.Array,
    state: RigLState,
    drop_fraction: jax.Array,
) -> tuple[RigLState, dict]:
    if weight.ndim == 3:  # stacked experts
        fn = jax.vmap(lambda w, g, m: rigl_update(spec, w, g, RigLState(m), drop_fraction))
        st, stats = fn(weight, dense_grad, state.mask)
        return st, stats

    mask = state.mask
    nnz = jnp.sum(mask)
    n_prune = jnp.floor(drop_fraction * nnz).astype(jnp.int32)

    survive = saliency.prune_survivors(weight, mask, n_prune)
    grown = saliency.top_k_candidates(jnp.abs(dense_grad), ~mask, n_prune)
    new_mask = survive | grown

    stats = dict(
        n_pruned=jnp.sum(mask & ~new_mask),
        n_grown=jnp.sum(grown),
        nnz=jnp.sum(new_mask),
        # neurons RigL implicitly ablated (all incoming weights pruned) — the
        # empirical observation motivating SRigL's explicit ablation (Fig. 3b):
        n_ablated=jnp.sum(jnp.sum(new_mask, axis=0) == 0),
    )
    return RigLState(mask=new_mask), stats
