"""Core of the paper's contribution: SRigL structured DST, baselines, theory."""
from repro.core.distributions import (  # noqa: F401
    LayerShape,
    erk_densities,
    fan_in_from_density,
    realized_sparsity,
    uniform_densities,
)
from repro.core.rigl import RigLSpec, RigLState, rigl_update  # noqa: F401
from repro.core.schedule import DSTSchedule  # noqa: F401
from repro.core.srigl import (  # noqa: F401
    LayerState,
    SRigLSpec,
    UpdateStats,
    apply_mask_for_forward,
    init_layer_state,
    srigl_update,
)
