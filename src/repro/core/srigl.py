"""SRigL — Structured RigL (Lasby et al., ICLR 2024), Section 3.1.

A sparse-to-sparse DST update that maintains a **constant fan-in** topology
(every active output neuron has exactly ``k`` non-zero incoming weights) and
performs **dynamic neuron ablation** controlled by ``gamma_sal``.

The update is a pure, jit-able function over fixed-shape arrays. The seven
steps of the paper map to the code as follows:

  1. prune criterion |W| (active), grow criterion |G| (inactive)   -> saliency.py
  2. K = drop_fraction * nnz (per layer, cosine-annealed)          -> schedule.py
  3. per-neuron salient count: survivors-of-prune + top-K-gradients
  4. ablate neurons with fewer than max(1, ceil(gamma_sal * k)) salient weights
  5. new fan-in k' = floor(target_nnz / n_active')  (floor => nnz never
     exceeds the per-layer budget; see the step-5 comment below)
  6. layer-wise prune of the K smallest-magnitude active weights
  7. per-neuron regrow by decreasing |G| until fan-in k'

Steps 6+7 (and the constant fan-in invariant) are realized in one shot by a
per-column priority ranking: survivors of the layer-wise prune always outrank
grow candidates (ranked by |G|), which outrank freshly-pruned weights (backup
tier so a column can always fill to k' exactly). Taking the top-k' of each
active column reproduces the sequential procedure with exact counts.

Ablation is re-evaluated from saliency at every update, so a previously-ablated
neuron *can* revive if enough of its (gradient-)salient weights reappear —
matching the "dynamically learns to ablate" framing of the paper.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import saliency


@dataclasses.dataclass(frozen=True)
class SRigLSpec:
    """Static per-layer configuration for SRigL."""

    name: str
    d_in: int
    d_out: int
    density: float              # from the ERK / uniform distribution
    gamma_sal: float = 0.3      # min fraction of salient weights per neuron
    ablation: bool = True       # neuron ablation enabled (SRigL w/ ablation)
    min_active_neurons: int = 1  # never ablate the whole layer

    @property
    def k0(self) -> int:
        """Initial constant fan-in."""
        return max(1, round(self.density * self.d_in))

    @property
    def target_nnz(self) -> int:
        """Per-neuron-matrix non-zero budget, constant through training."""
        return self.k0 * self.d_out


class LayerState(NamedTuple):
    """Dynamic per-layer DST state (a pytree; shards with the weight)."""

    mask: jax.Array           # bool (d_in, d_out)
    neuron_active: jax.Array  # bool (d_out,)


class UpdateStats(NamedTuple):
    n_pruned: jax.Array
    n_grown: jax.Array
    n_ablated: jax.Array
    fan_in: jax.Array
    nnz: jax.Array


def init_layer_state(key: jax.Array, spec: SRigLSpec) -> LayerState:
    from repro.core import topology

    mask = topology.random_constant_fan_in_mask(key, spec.d_in, spec.d_out, spec.k0)
    return LayerState(mask=mask, neuron_active=jnp.ones((spec.d_out,), bool))


def srigl_update(
    spec: SRigLSpec,
    weight: jax.Array,
    dense_grad: jax.Array,
    state: LayerState,
    drop_fraction: jax.Array,
) -> tuple[LayerState, UpdateStats]:
    """One SRigL topology update for a single (d_in, d_out) layer.

    For stacked layers (e.g. MoE experts with weight (E, d_in, d_out)), vmap
    this function over the leading axis — each expert then runs its own
    layer-wise prune/grow/ablate, the natural per-replica analog.
    """
    if weight.ndim == 3:  # stacked replicas (experts)
        fn = jax.vmap(lambda w, g, m, a: srigl_update(
            spec, w, g, LayerState(m, a), drop_fraction))
        st, stats = fn(weight, dense_grad, state.mask, state.neuron_active)
        return st, stats

    mask, active_old = state.mask, state.neuron_active
    w_mag = jnp.abs(weight)
    g_mag = jnp.abs(dense_grad)

    # -- step 2: number of weights to prune & grow this update -------------
    nnz = jnp.sum(mask)
    n_prune = jnp.floor(drop_fraction * nnz).astype(jnp.int32)

    # -- step 6 (criterion side): survivors of the layer-wise prune --------
    # layer-wise top-(A-K) by |w| via sharded bisection thresholding (exact
    # up to fp-quantile resolution; see saliency.topk_threshold)
    survive = saliency.select_topk_threshold(w_mag, mask, nnz - n_prune)

    # -- step 1+3: per-neuron salient counts -------------------------------
    grow_salient = saliency.select_topk_threshold(g_mag, ~mask, n_prune)
    sal_per_neuron = jnp.sum(survive, axis=0) + jnp.sum(grow_salient, axis=0)

    # -- step 4: ablation ---------------------------------------------------
    n_active_old = jnp.maximum(jnp.sum(active_old), 1)
    k_cur = jnp.maximum(nnz // n_active_old, 1)
    tau = jnp.maximum(jnp.ceil(spec.gamma_sal * k_cur), 1.0)
    if spec.ablation:
        active_new = sal_per_neuron >= tau
        # Never ablate below min_active_neurons: force-keep the most salient.
        neuron_rank = saliency.descending_ranks(sal_per_neuron.astype(jnp.float32))
        active_new = active_new | (neuron_rank < spec.min_active_neurons)
    else:
        active_new = jnp.ones_like(active_old)

    # -- step 5: new constant fan-in ----------------------------------------
    # floor (not round) keeps nnz = k' * n_active' <= target_nnz exact: the
    # budget never grows across updates. target_nnz = k0*d_out >= d_out >=
    # n_active', so floor >= 1 and the lower clip never inflates the budget.
    n_active_new = jnp.maximum(jnp.sum(active_new), 1)
    k_new = jnp.clip(spec.target_nnz // n_active_new, 1, spec.d_in)
    k_new = k_new.astype(jnp.int32)

    # -- steps 6+7: build the new mask by per-column priority ---------------
    w_norm = saliency.normalized(weight, mask)       # in [0, 1]
    g_norm = saliency.normalized(dense_grad, ~mask)  # in [0, 1]
    priority = jnp.where(
        survive, 2.0 + w_norm,                        # tier 3: prune survivors
        jnp.where(~mask, g_norm,                      # tier 2: grow by |G|
                  -2.0 + w_norm))                     # tier 1: freshly pruned (backup)
    col_rank = saliency.descending_ranks(priority, axis=0)
    new_mask = (col_rank < k_new) & active_new[None, :]

    new_nnz = jnp.sum(new_mask)
    stats = UpdateStats(
        n_pruned=jnp.sum(mask & ~new_mask),
        n_grown=jnp.sum(~mask & new_mask),
        n_ablated=jnp.sum(active_old & ~active_new),
        fan_in=k_new,
        nnz=new_nnz,
    )
    return LayerState(mask=new_mask, neuron_active=active_new), stats


def apply_mask_for_forward(weight: jax.Array, mask: jax.Array) -> jax.Array:
    """Masked weight whose *gradient is dense* (straight-through on the mask).

    forward:  w * mask
    backward: dL/dw = dL/d(w*mask) (un-masked) — exactly the dense gradient
              RigL/SRigL need for the grow criterion. The optimizer re-masks.
    """
    m = mask.astype(weight.dtype)
    return weight - jax.lax.stop_gradient(weight * (1 - m))
