"""SET baseline (Mocanu et al. 2018) — prune by magnitude, regrow *randomly*.

Included because the paper's Table 3 compares against it; shares the rank
machinery with RigL/SRigL.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import saliency
from repro.core.rigl import RigLSpec, RigLState, init_layer_state  # noqa: F401 (re-export)


def set_update(
    spec: RigLSpec,
    weight: jax.Array,
    key: jax.Array,
    state: RigLState,
    drop_fraction: jax.Array,
) -> tuple[RigLState, dict]:
    if weight.ndim == 3:
        keys = jax.random.split(key, weight.shape[0])
        fn = jax.vmap(lambda w, k, m: set_update(spec, w, k, RigLState(m), drop_fraction))
        st, stats = fn(weight, keys, state.mask)
        return st, stats

    mask = state.mask
    nnz = jnp.sum(mask)
    n_prune = jnp.floor(drop_fraction * nnz).astype(jnp.int32)

    survive = saliency.prune_survivors(weight, mask, n_prune)
    rand = jax.random.uniform(key, weight.shape)
    grown = saliency.top_k_candidates(rand, ~mask, n_prune)
    new_mask = survive | grown

    stats = dict(n_pruned=jnp.sum(mask & ~new_mask), n_grown=jnp.sum(grown), nnz=jnp.sum(new_mask))
    return RigLState(mask=new_mask), stats
