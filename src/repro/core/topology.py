"""Sparse-topology utilities: mask initialization, condensed<->dense conversion.

Conventions
-----------
A sparse linear layer computes ``y = x @ W`` with ``W`` of shape ``(d_in, d_out)``.
The **constant fan-in** constraint requires every *column* of ``W`` (one output
neuron) to have exactly ``k`` non-zeros.

The **condensed representation** stores such a matrix as two dense arrays:

  values  : (d_out, k)  — the non-zero weights of each neuron
  indices : (d_out, k)  — the input-feature index of each non-zero (int32)

Padding slots (columns with fewer than k non-zeros, including fully-ablated
neurons) carry ``values`` 0 and an ``indices`` entry pointing at an INACTIVE
row of that column (mask False there). That invariant makes a values-only
refresh exact: re-gathering ``(w * mask)`` at the stored indices reproduces 0
for every padding slot without a duplicate contribution — the incremental
serving export (repro.sparse.plan.Plan.refresh) relies on it to update
weights under unchanged topology without re-sorting. A separate
``neuron_active`` bool vector tracks ablation for the structured
(row-removal) execution path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Mask initialization
# ---------------------------------------------------------------------------

def random_constant_fan_in_mask(key: jax.Array, d_in: int, d_out: int, k: int) -> jax.Array:
    """Boolean mask (d_in, d_out) with exactly k True per column, uniform at random."""
    if not 1 <= k <= d_in:
        raise ValueError(f"fan-in k={k} must be in [1, {d_in}]")
    # Per-column random priorities; take top-k positions per column.
    scores = jax.random.uniform(key, (d_in, d_out))
    ranks = jnp.argsort(jnp.argsort(-scores, axis=0), axis=0)  # rank 0 = largest
    return ranks < k


def random_unstructured_mask(key: jax.Array, d_in: int, d_out: int, nnz: int) -> jax.Array:
    """Boolean mask (d_in, d_out) with exactly nnz True, uniform over the matrix."""
    total = d_in * d_out
    if not 0 <= nnz <= total:
        raise ValueError(f"nnz={nnz} out of range [0, {total}]")
    scores = jax.random.uniform(key, (total,))
    ranks = jnp.argsort(jnp.argsort(-scores))
    return (ranks < nnz).reshape(d_in, d_out)


def random_nm_mask(key: jax.Array, d_in: int, d_out: int, n: int, m: int) -> jax.Array:
    """Classic N:M mask (N non-zeros per M *contiguous* fan-in weights).

    Constant fan-in (the paper's structure) is the special case M = d_in;
    this utility covers the hardware-2:4 style patterns the paper relates to
    (Sec. 2, Mishra et al. 2021) for comparison studies.
    """
    if d_in % m:
        raise ValueError(f"d_in={d_in} not divisible by M={m}")
    if not 1 <= n <= m:
        raise ValueError(f"need 1 <= N <= M, got {n}:{m}")
    scores = jax.random.uniform(key, (d_in // m, m, d_out))
    ranks = jnp.argsort(jnp.argsort(-scores, axis=1), axis=1)
    return (ranks < n).reshape(d_in, d_out)


def check_nm(mask: np.ndarray, n: int, m: int) -> bool:
    """True iff every contiguous M-group along fan-in has exactly N non-zeros."""
    a = np.asarray(mask)
    groups = a.reshape(a.shape[0] // m, m, a.shape[1]).sum(axis=1)
    return bool(np.all(groups == n))


# ---------------------------------------------------------------------------
# Condensed <-> dense
# ---------------------------------------------------------------------------

def dense_to_condensed(weight: jax.Array, mask: jax.Array, k: int):
    """Convert masked dense (d_in, d_out) to condensed (values, indices) of shape (d_out, k).

    Requires every column of ``mask`` to have at most k True. Columns with
    fewer than k non-zeros (e.g. ablated neurons) are padded with value 0 and
    an index pointing at an inactive row of that column (the row order ranks
    active rows first, so slots past a column's nnz land on mask-False rows) —
    see the module docstring for why padding must NOT alias an active row.
    """
    d_in, d_out = weight.shape
    # Rank active entries first within each column (stable => ascending row order).
    priority = jnp.where(mask, 1.0, 0.0)
    order = jnp.argsort(-priority, axis=0, stable=True)  # (d_in, d_out): active rows first
    top_idx = order[:k, :].T.astype(jnp.int32)  # (d_out, k)
    gathered_mask = jnp.take_along_axis(mask.T, top_idx, axis=1)
    values = jnp.take_along_axis(weight.T, top_idx, axis=1) * gathered_mask
    return values, top_idx


def condensed_to_dense(values: jax.Array, indices: jax.Array, d_in: int):
    """Scatter condensed (d_out, k) arrays back to a dense (d_in, d_out) matrix."""
    d_out, k = values.shape
    dense = jnp.zeros((d_out, d_in), values.dtype)
    rows = jnp.arange(d_out)[:, None].repeat(k, axis=1)
    dense = dense.at[rows.reshape(-1), indices.reshape(-1)].add(values.reshape(-1))
    return dense.T


# ---------------------------------------------------------------------------
# Invariant checks (host-side, for tests / debugging)
# ---------------------------------------------------------------------------

def column_nnz(mask: jax.Array) -> jax.Array:
    """Number of non-zeros per output neuron (column)."""
    return jnp.sum(mask.astype(jnp.int32), axis=0)


def check_constant_fan_in(mask: np.ndarray, k: int, neuron_active: np.ndarray | None = None) -> bool:
    """True iff every active column has exactly k non-zeros and inactive ones have 0."""
    nnz = np.asarray(mask).sum(axis=0)
    if neuron_active is None:
        return bool(np.all(nnz == k))
    neuron_active = np.asarray(neuron_active)
    ok_active = np.all(nnz[neuron_active] == k) if neuron_active.any() else True
    ok_ablated = np.all(nnz[~neuron_active] == 0) if (~neuron_active).any() else True
    return bool(ok_active and ok_ablated)
