"""FLOPs accounting following the paper's methodology (Table 5, Appendix G).

Only operations induced by linear/matmul layers (and their activations are
ignored, as are adds/pools/norms, per Evci et al. 2021's MicroNet-style count).
Sparse layers count 2 * nnz FLOPs per token for the forward pass; the backward
pass costs 2x the forward (grad-wrt-input + grad-wrt-weight matmuls), so one
training step costs 3x inference. DST mask updates are amortized over delta_t
steps and ignored (paper App. G).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class LinearCost:
    name: str
    d_in: int
    d_out: int
    density: float = 1.0     # fraction of weights active
    n_replicas: int = 1      # experts etc.
    tokens_scale: float = 1.0  # fraction of tokens hitting this layer (MoE top-k/E)

    @property
    def nnz(self) -> float:
        return self.d_in * self.d_out * self.density * self.n_replicas

    def fwd_flops_per_token(self) -> float:
        return 2.0 * self.d_in * self.d_out * self.density * self.tokens_scale * (
            self.n_replicas if self.tokens_scale == 1.0 else 1.0
        )


def inference_flops(layers: Sequence[LinearCost], tokens: int) -> float:
    """Forward FLOPs for ``tokens`` tokens."""
    return tokens * sum(l.fwd_flops_per_token() for l in layers)


def training_flops(layers: Sequence[LinearCost], tokens_per_step: int, steps: int) -> float:
    """fwd + 2x bwd = 3x fwd, as in the paper's Table 5 methodology."""
    return 3.0 * steps * inference_flops(layers, tokens_per_step)


def sparse_vs_dense_ratio(layers: Sequence[LinearCost]) -> float:
    """FLOPs ratio sparse/dense for one forward pass (Table 5 column ratio)."""
    sparse = sum(l.fwd_flops_per_token() for l in layers)
    dense = sum(
        dataclasses.replace(l, density=1.0).fwd_flops_per_token() for l in layers
    )
    return sparse / max(dense, 1e-12)
