"""DST connectivity-update schedules.

RigL / SRigL update the sparse topology every ``delta_t`` optimizer steps. The
fraction of active weights pruned (and regrown) at update time follows a cosine
annealing schedule (Dettmers & Zettlemoyer 2019):

    alpha_t = alpha/2 * (1 + cos(pi * t / t_end))   for t < t_end, else 0

with alpha = 0.3 and t_end = 75% of total training steps by default (paper D.1).
All functions are traceable (usable inside jit).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DSTSchedule:
    delta_t: int = 100          # steps between topology updates
    alpha: float = 0.3          # initial drop fraction
    t_end_fraction: float = 0.75
    total_steps: int = 100_000
    grad_accum_steps: int = 1   # dense-grad averaging window before an update

    @property
    def t_end(self) -> int:
        return int(self.t_end_fraction * self.total_steps)

    def drop_fraction(self, step) -> jnp.ndarray:
        """Cosine-annealed drop fraction at ``step`` (0 after t_end)."""
        t = jnp.asarray(step, jnp.float32)
        t_end = jnp.float32(max(self.t_end, 1))
        frac = 0.5 * self.alpha * (1.0 + jnp.cos(jnp.pi * jnp.minimum(t, t_end) / t_end))
        return jnp.where(t < t_end, frac, 0.0)

    def is_update_step(self, step) -> jnp.ndarray:
        """True on steps where the topology is updated (and before t_end)."""
        step = jnp.asarray(step)
        due = (step % self.delta_t == 0) & (step > 0)
        return due & (step < self.t_end)
