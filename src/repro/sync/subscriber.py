"""Replica-side generation handshake: adversarial streams -> coherent state.

The subscriber owns the robustness story of the protocol. It keeps a single
monotonically increasing ``generation`` and per-stack ``mask_versions``, and
enforces:

- **bootstrap**: nothing applies before a ``Snapshot`` (deltas seen first
  trigger a resync request instead of a partial state);
- **stale/duplicate**: records at ``generation <= current`` are counted and
  dropped;
- **reorder**: future deltas buffer until the chain ``current+1, +2, ...``
  is contiguous, then drain in order;
- **gap**: a missing generation (buffered deltas strictly ahead of
  ``current+1``) requests a full-snapshot resync -- at most one outstanding
  request per missing generation, so a polling loop does not spam the
  publisher;
- **all-or-nothing commit**: a delta is validated completely (stack-name
  set, per-stack version monotonicity, values-merge shape compatibility)
  BEFORE anything mutates; a failed record is counted ``rejected``, triggers
  a resync, and leaves every stack exactly as it was. A replica's stacks are
  never mutually incoherent.

State is host-side numpy; ``consume_changes()`` hands the engine the set of
stacks/dense paths touched since it last drained, so the donated device-side
apply only walks what moved.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.sync import delta as D


class SyncProtocolError(RuntimeError):
    """A record that decoded fine but cannot be applied coherently."""


_COUNTER_KEYS = ("received", "applied_deltas", "applied_snapshots", "stale",
                 "duplicate", "corrupt", "rejected", "gaps", "resyncs",
                 "bytes_deltas", "bytes_snapshots")


class Subscriber:
    """Tails one channel subscription and converges on the publisher."""

    def __init__(self, subscription, name: str = "replica"):
        self.subscription = subscription
        self.name = name
        self.generation: int | None = None     # None until bootstrap
        self.meta: dict = {}
        self.mask_versions: dict[str, int] = {}
        self.leaves: dict[str, D.StackDelta] = {}   # merged topology records
        self.params: dict[str, np.ndarray] = {}     # flattened host tree
        self.masks: dict[str, np.ndarray] = {}
        self.counters: dict[str, int] = {k: 0 for k in _COUNTER_KEYS}
        self._buffer: dict[int, D.Delta] = {}
        self._resync_requested_for: set[int] = set()
        # change tracking for consume_changes()
        self._pending_stacks: dict[str, set[str]] = {}
        self._pending_dense: set[str] = set()
        self._pending_snapshot = False

    # -- polling ------------------------------------------------------------

    def poll(self) -> int:
        """Drain the subscription, apply what is coherent. Returns how many
        records were applied (deltas + snapshots)."""
        applied = 0
        for blob in self.subscription.recv_new():
            if not blob:            # pruned/blank entry
                continue
            self.counters["received"] += 1
            try:
                rec = D.decode(blob)
            except D.DeltaCorruptError:
                self.counters["corrupt"] += 1
                continue
            if rec.kind == "snapshot":
                applied += self._offer_snapshot(rec, len(blob))
            else:
                self._offer_delta(rec, len(blob))
        applied += self._drain_buffer()
        self._maybe_request_resync()
        return applied

    def _offer_snapshot(self, snap: D.Snapshot, nbytes: int) -> int:
        if self.generation is not None and snap.generation <= self.generation:
            self.counters["stale"] += 1
            return 0
        self._apply_snapshot(snap)
        self.counters["applied_snapshots"] += 1
        self.counters["bytes_snapshots"] += nbytes
        # buffered deltas at or below the snapshot are subsumed
        self._buffer = {g: d for g, d in self._buffer.items()
                        if g > snap.generation}
        self._resync_requested_for.clear()
        return 1

    def _offer_delta(self, delta: D.Delta, nbytes: int) -> None:
        gen = delta.generation
        if self.generation is not None and gen <= self.generation:
            self.counters["stale" if gen < self.generation
                          else "duplicate"] += 1
            return
        if gen in self._buffer:
            self.counters["duplicate"] += 1
            return
        self._buffer[gen] = delta
        self.counters["bytes_deltas"] += nbytes

    def _drain_buffer(self) -> int:
        applied = 0
        while (self.generation is not None
               and (self.generation + 1) in self._buffer):
            delta = self._buffer.pop(self.generation + 1)
            try:
                self._apply_delta(delta)
            except SyncProtocolError:
                self.counters["rejected"] += 1
                # incoherent record: nothing was mutated; fall back to resync
                self._request_resync(delta.generation,
                                     reason="rejected delta")
                break
            applied += 1
            self.counters["applied_deltas"] += 1
        return applied

    def _maybe_request_resync(self) -> None:
        if not self._buffer:
            return
        if self.generation is None:
            # deltas but no bootstrap yet
            self._request_resync(min(self._buffer), reason="no snapshot")
            return
        need = self.generation + 1
        if min(self._buffer) > need:
            self.counters["gaps"] += 1
            self._request_resync(need, reason=f"gap at generation {need}")

    def _request_resync(self, needed_gen: int, *, reason: str) -> None:
        if needed_gen in self._resync_requested_for:
            return
        self._resync_requested_for.add(needed_gen)
        self.counters["resyncs"] += 1
        self.subscription.request_resync(
            f"{reason} (subscriber={self.name})",
            needed_generation=needed_gen)

    # -- application (all-or-nothing) ---------------------------------------

    def _apply_snapshot(self, snap: D.Snapshot) -> None:
        self.meta = dict(snap.meta)
        self.mask_versions = dict(snap.mask_versions)
        self.leaves = {rec.name: rec for rec in snap.stacks}
        self.params = dict(snap.params)
        self.masks = dict(snap.masks)
        self.generation = snap.generation
        self._pending_snapshot = True
        self._pending_stacks = {name: set(rec.arrays)
                                for name, rec in self.leaves.items()}
        self._pending_dense = set(self.params)

    def _validate_delta(self, delta: D.Delta) -> None:
        names = {rec.name for rec in delta.stacks}
        if names != set(self.leaves):
            raise SyncProtocolError(
                f"delta gen {delta.generation} covers stacks "
                f"{sorted(names)} but replica holds {sorted(self.leaves)}")
        for rec in delta.stacks:
            cur_v = self.mask_versions[rec.name]
            if rec.mode == "topology":
                if rec.mask_version < cur_v:
                    raise SyncProtocolError(
                        f"{rec.name}: topology mask_version "
                        f"{rec.mask_version} < current {cur_v}")
            elif rec.mode == "values":
                if rec.mask_version != cur_v:
                    raise SyncProtocolError(
                        f"{rec.name}: values-only record at mask_version "
                        f"{rec.mask_version} but replica is at {cur_v}")
                stored = self.leaves[rec.name]
                for field, arr in rec.arrays.items():
                    old = stored.arrays.get(field)
                    if old is None or old.shape != arr.shape:
                        raise SyncProtocolError(
                            f"{rec.name}.{field}: values merge shape "
                            f"mismatch ({None if old is None else old.shape}"
                            f" vs {arr.shape})")
            else:
                raise SyncProtocolError(
                    f"{rec.name}: unknown record mode {rec.mode!r}")

    def _apply_delta(self, delta: D.Delta) -> None:
        # validate EVERYTHING before mutating ANYTHING
        self._validate_delta(delta)
        for rec in delta.stacks:
            pending = self._pending_stacks.setdefault(rec.name, set())
            if rec.mode == "topology":
                self.leaves[rec.name] = rec
                self.mask_versions[rec.name] = rec.mask_version
                pending.update(rec.arrays)
                pending.add("__topology__")
            else:
                stored = self.leaves[rec.name]
                merged = dict(stored.arrays)
                merged.update(rec.arrays)
                self.leaves[rec.name] = D.StackDelta(
                    name=stored.name, mask_version=stored.mask_version,
                    mode="topology", format=stored.format,
                    static=stored.static, arrays=merged)
                pending.update(rec.arrays)
        for path, arr in delta.dense.items():
            self.params[path] = arr
            self._pending_dense.add(path)
        self.generation = delta.generation

    # -- consumers ----------------------------------------------------------

    def consume_changes(self) -> dict:
        """What moved since the engine last drained: per-stack changed field
        sets, dense param paths, and whether a wholesale snapshot landed."""
        out = {"stacks": self._pending_stacks,
               "dense": self._pending_dense,
               "snapshot": self._pending_snapshot}
        self._pending_stacks = {}
        self._pending_dense = set()
        self._pending_snapshot = False
        return out

    def masks_tree(self) -> dict:
        return D.unflatten_tree(
            {k: jnp.asarray(v) for k, v in self.masks.items()})

    def params_tree(self) -> dict:
        return D.unflatten_tree(
            {k: jnp.asarray(v) for k, v in self.params.items()})

    def wait_for_bootstrap(self, timeout: float = 10.0,
                           interval: float = 0.05) -> bool:
        """Poll until a snapshot lands (multi-process startup helper)."""
        deadline = time.monotonic() + timeout
        while self.generation is None:
            self.poll()
            if self.generation is not None:
                break
            if time.monotonic() >= deadline:
                return False
            time.sleep(interval)
        return True


def engine_from_snapshot(cfg, subscriber: Subscriber, *, registry=None,
                         **engine_kwargs):
    """Build a live ``ServingEngine`` from a bootstrapped subscriber and
    attach it, so subsequent deltas drain at paged-chunk boundaries.

    The engine gets FRESH device buffers (built from the snapshot's host
    arrays), which is what makes later donation safe: no other live object
    aliases them.
    """
    from repro.launch import engine as ENG
    from repro.sparse import registry as REG

    subscriber.poll()
    if subscriber.generation is None:
        raise SyncProtocolError(
            "subscriber has no snapshot yet; wait_for_bootstrap() first")
    meta = subscriber.meta
    registry = registry if registry is not None else REG.build_registry(cfg)
    eng = ENG.ServingEngine(
        cfg, subscriber.params_tree(), subscriber.masks_tree(), registry,
        path=meta.get("path", "condensed"),
        values_dtype=meta.get("values_dtype"),
        mask_versions={k: int(v)
                       for k, v in subscriber.mask_versions.items()},
        **engine_kwargs)
    if int(meta.get("tp", 1)) != int(getattr(eng, "tp", 1)):
        raise SyncProtocolError(
            f"publisher tp={meta.get('tp')} but engine tp={eng.tp}; "
            f"pass tp/mesh matching the published layout")
    eng.attach_subscriber(subscriber)
    return eng
