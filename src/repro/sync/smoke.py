"""Blocking CI smoke for the sync subsystem: file-channel pub/sub e2e.

  PYTHONPATH=src python -m repro.sync.smoke

Runs the whole protocol against a temp directory, asserting (exit != 0 on
any failure):

1. snapshot bootstrap over the file channel;
2. values-only and topology deltas applied in order, bitwise-converged
   against the publisher's plan;
3. ONE INJECTED GAP — a delta file is deleted before the subscriber sees
   it — detected, resynced via the request-file back-channel, converged;
4. a live ServingEngine (real smoke model) drains a topology delta at a
   chunk boundary with zero decode recompiles and donated buffers.

These are correctness assertions (no timing), so the CI step is BLOCKING.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.launch import engine as ENG
from repro.models import model as M
from repro.sparse import registry as REG
from repro.sync import DirChannel, Publisher, Subscriber, engine_from_snapshot


def _check(ok: bool, what: str) -> None:
    status = "ok" if ok else "FAIL"
    print(f"[sync-smoke] {status}: {what}")
    if not ok:
        sys.exit(1)


def _bitwise_converged(sub, pub, reg) -> bool:
    host = jax.device_get(
        {s.name: REG.get_path(pub._plan.serving_tree, s.path) for s in reg})
    for s in reg:
        rec = sub.leaves[s.name]
        for f in host[s.name]._array_fields:
            theirs = getattr(host[s.name], f)
            mine = rec.arrays.get(f)
            if (mine is None) != (theirs is None):
                return False
            if mine is not None and not np.array_equal(
                    mine, np.asarray(theirs)):
                return False
    return True


def _train_step(reg, params, masks, versions, *, rewire: bool):
    params = jax.tree_util.tree_map(
        lambda x: x * 1.003 if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
    if rewire:
        s = reg[0]
        masks = jax.tree_util.tree_map(lambda x: x, masks)
        REG.set_path(masks, s.path,
                     jnp.roll(REG.get_path(masks, s.path), 1, axis=-2))
        versions = dict(versions)
        versions[s.name] += 1
    return params, masks, versions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke_config(args.arch)
    key = jax.random.PRNGKey(0)
    reg = REG.build_registry(cfg)
    params = M.init_params(cfg, key, REG.k_fan_map(cfg, reg))
    masks = REG.init_sparsity_state(cfg, key, reg)["masks"]
    versions = {s.name: 0 for s in reg}

    with tempfile.TemporaryDirectory(prefix="repro-sync-") as tmp:
        ch = DirChannel(tmp)
        pub = Publisher(cfg, reg, ch, path="condensed", batch_size=2,
                        arch=args.arch)
        info = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        print(f"[sync-smoke] gen {info['generation']} {info['kind']} "
              f"({info['bytes']} B)")

        sub = Subscriber(ch.subscribe("smoke"), name="smoke")
        _check(sub.wait_for_bootstrap(timeout=5.0), "snapshot bootstrap")
        eng = engine_from_snapshot(cfg, sub, registry=reg, gen_chunk=4)

        # -- values-only then topology deltas, applied live -----------------
        prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                     cfg.vocab_size)
        rid = eng.submit(prompts, 16)
        eng.step(max_chunks=2)

        params, masks, versions = _train_step(reg, params, masks, versions,
                                              rewire=False)
        info = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        _check(info["topology"] == [] and info["values_bytes"] > 0,
               f"gen {info['generation']} values-only delta "
               f"({info['bytes']} B)")
        params, masks, versions = _train_step(reg, params, masks, versions,
                                              rewire=True)
        info = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        _check(len(info["topology"]) == 1,
               f"gen {info['generation']} topology delta "
               f"({info['bytes']} B, {info['topology']})")

        n_jit = ENG._jit_entries(ENG._paged_decode_chunk)
        eng.step()
        eng.retire(rid)
        _check(eng._sync_generation == pub.generation,
               f"engine drained to gen {eng._sync_generation}")
        _check(ENG._jit_entries(ENG._paged_decode_chunk) == n_jit,
               "zero decode recompiles across the mid-stream update")
        _check(_bitwise_converged(sub, pub, reg),
               "subscriber bitwise-converged with publisher")

        # -- injected gap -> resync ------------------------------------------
        params, masks, versions = _train_step(reg, params, masks, versions,
                                              rewire=True)
        info = pub.publish(params=params, masks=masks,
                           mask_versions=versions)
        gap_file = os.path.join(tmp, f"{info['generation']:010d}-delta.rsd")
        os.remove(gap_file)          # the subscriber never sees this one
        params, masks, versions = _train_step(reg, params, masks, versions,
                                              rewire=False)
        pub.publish(params=params, masks=masks, mask_versions=versions)
        sub.poll()
        _check(sub.counters["gaps"] >= 1 and sub.counters["resyncs"] >= 1,
               f"injected gap detected (gaps={sub.counters['gaps']}, "
               f"resync requested)")
        served = pub.serve_resyncs()
        _check(served >= 1, f"publisher answered {served} resync request(s)")
        sub.poll()
        _check(sub.generation == pub.generation,
               f"resynced to gen {sub.generation}")
        _check(_bitwise_converged(sub, pub, reg),
               "post-resync bitwise convergence")
        print(f"[sync-smoke] counters: "
              f"{ {k: v for k, v in sub.counters.items() if v} }")
    print("[sync-smoke] PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
