"""Transport layer: how encoded records move from publisher to subscribers.

Two channels share one tiny interface:

publisher side::

    channel.send(blob, kind=..., generation=...)
    channel.poll_requests() -> list[dict]     # drained resync requests

subscriber side::

    sub = channel.subscribe(name)
    sub.recv_new() -> list[bytes]             # blobs not yet seen by THIS sub
    sub.request_resync(reason)

``QueueChannel`` is in-process (tests, co-located trainer+engine).
``DirChannel`` is the multi-process fleet transport: the publisher writes
each record to a tmp file and atomically ``os.replace``-renames it into the
directory as ``<generation:010d>-<kind>.rsd``, so a tailing subscriber never
observes a torn file and lexical filename order IS generation order. Resync
requests travel the other way as small ``request-*.req`` JSON files the
publisher drains and deletes.

Neither channel deduplicates, orders, or retains forever -- the subscriber's
generation handshake (``sync/subscriber.py``) owns robustness; ``DirChannel``
prunes old delta files (``retain``), which is exactly how a slow subscriber
ends up with a gap and exercises the resync path.
"""
from __future__ import annotations

import json
import os
import uuid


# ---------------------------------------------------------------------------
# in-process queue channel
# ---------------------------------------------------------------------------

class _QueueSubscription:
    def __init__(self, channel: "QueueChannel", name: str):
        self._channel = channel
        self._name = name
        self._cursor = 0

    def recv_new(self) -> list:
        log = self._channel._log
        new = [blob for _, blob in log[self._cursor:]]
        self._cursor = len(log)
        return new

    def request_resync(self, reason: str = "",
                       needed_generation: int | None = None) -> None:
        self._channel._requests.append(
            {"subscriber": self._name, "reason": reason,
             "needed_generation": needed_generation})


class QueueChannel:
    """Shared-memory channel: an append-only log + per-subscriber cursors."""

    def __init__(self, retain: int = 64):
        self._log: list[tuple[dict, bytes]] = []
        self._requests: list[dict] = []
        self.retain = retain

    def send(self, blob: bytes, *, kind: str, generation: int) -> None:
        self._log.append(({"kind": kind, "generation": int(generation)},
                          bytes(blob)))
        # cap memory; cursors index into the live list so prune by marking,
        # not slicing (a slice would silently re-deliver to every cursor)
        if len(self._log) > self.retain:
            drop = len(self._log) - self.retain
            self._log[:drop] = [(m, b"") for m, b in self._log[:drop]]

    def poll_requests(self) -> list[dict]:
        out, self._requests = self._requests, []
        return out

    def subscribe(self, name: str = "replica") -> _QueueSubscription:
        return _QueueSubscription(self, name)


# ---------------------------------------------------------------------------
# file/directory channel
# ---------------------------------------------------------------------------

_RECORD_SUFFIX = ".rsd"
_REQUEST_SUFFIX = ".req"


class _DirSubscription:
    def __init__(self, channel: "DirChannel", name: str):
        self._channel = channel
        self._name = name
        self._seen: set[str] = set()

    def recv_new(self) -> list:
        blobs = []
        for fname in self._channel._list_records():
            if fname in self._seen:
                continue
            self._seen.add(fname)
            try:
                with open(os.path.join(self._channel.dirpath, fname),
                          "rb") as f:
                    blobs.append(f.read())
            except OSError:
                # pruned between listdir and open: the generation handshake
                # treats the hole like any other dropped delta
                continue
        return blobs

    def request_resync(self, reason: str = "",
                       needed_generation: int | None = None) -> None:
        payload = json.dumps({"subscriber": self._name, "reason": reason,
                              "needed_generation": needed_generation})
        fname = f"request-{self._name}-{uuid.uuid4().hex}{_REQUEST_SUFFIX}"
        _atomic_write(self._channel.dirpath, fname, payload.encode("utf-8"))


def _atomic_write(dirpath: str, fname: str, data: bytes) -> None:
    tmp = os.path.join(dirpath, f".tmp-{uuid.uuid4().hex}")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, os.path.join(dirpath, fname))


class DirChannel:
    """Atomically-renamed record files in a shared directory.

    File name ``<generation:010d>-<kind>.rsd`` makes lexical order equal
    generation order and lets pruning keep the newest ``retain`` records
    plus always the newest snapshot (a subscriber can bootstrap any time).
    """

    def __init__(self, dirpath: str, *, retain: int = 16):
        self.dirpath = str(dirpath)
        self.retain = retain
        os.makedirs(self.dirpath, exist_ok=True)

    def _list_records(self) -> list[str]:
        try:
            names = os.listdir(self.dirpath)
        except OSError:
            return []
        return sorted(n for n in names if n.endswith(_RECORD_SUFFIX))

    def send(self, blob: bytes, *, kind: str, generation: int) -> None:
        fname = f"{int(generation):010d}-{kind}{_RECORD_SUFFIX}"
        _atomic_write(self.dirpath, fname, bytes(blob))
        self._prune()

    def _prune(self) -> None:
        records = self._list_records()
        if len(records) <= self.retain:
            return
        snapshots = [n for n in records if n.endswith(
            f"-snapshot{_RECORD_SUFFIX}")]
        keep = set(records[-self.retain:])
        if snapshots:
            keep.add(snapshots[-1])
        for n in records:
            if n not in keep:
                try:
                    os.remove(os.path.join(self.dirpath, n))
                except OSError:
                    pass

    def poll_requests(self) -> list[dict]:
        out = []
        try:
            names = sorted(os.listdir(self.dirpath))
        except OSError:
            return out
        for n in names:
            if not n.endswith(_REQUEST_SUFFIX):
                continue
            path = os.path.join(self.dirpath, n)
            try:
                with open(path, "r", encoding="utf-8") as f:
                    out.append(json.load(f))
            except (OSError, json.JSONDecodeError):
                pass
            try:
                os.remove(path)
            except OSError:
                pass
        return out

    def subscribe(self, name: str = "replica") -> _DirSubscription:
        return _DirSubscription(self, name)
