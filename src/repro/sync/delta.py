"""Wire format for the train->serve sync protocol.

One record = one generation's worth of change, encoded as::

    MAGIC(4) | header_len(u32) | payload_len(u32) | header JSON | payload | crc32(u32)

The header is compact sorted-key JSON describing every array in the payload
(name, field, dtype, shape, byte offset); the payload is the raw little-endian
array bytes concatenated in header order; the trailing CRC32 covers header +
payload. Decoding verifies magic, lengths, and checksum before touching any
bytes -- a torn or corrupt file raises :class:`DeltaCorruptError` and the
subscriber counts + drops it instead of applying garbage.

Two record kinds:

- ``Delta``: per-stack :class:`StackDelta` records (mode ``"topology"`` ships
  the full condensed leaf -- indices + values + scales/out_index where
  present -- mode ``"values"`` ships only the value-stream fields for stacks
  whose mask did not move) plus the dense (non-stack) parameter leaves, which
  train every step too and are required for token identity.
- ``Snapshot``: the full flattened params + masks trees, per-stack topology
  records, and the plan meta (path / values_dtype / tp) a subscriber needs to
  bootstrap or resync from nothing.

Everything here is host-side numpy; the publisher does ONE fused
``jax.device_get`` before encoding and the subscriber moves arrays back to
device only when a leaf is adopted into a live plan.
"""
from __future__ import annotations

import dataclasses
import json
import struct
import zlib

import numpy as np
import jax.numpy as jnp

from repro.sparse import formats as F

_MAGIC = b"RSY1"
_LEN = struct.Struct("<II")
_CRC = struct.Struct("<I")


class DeltaCorruptError(ValueError):
    """Record failed magic/length/checksum/structure validation."""


# dtypes that may legally appear on the wire. bfloat16 / float8 are the
# ml_dtypes-backed extension types jax registers with numpy -- ``dtype.name``
# is canonical for them, but ``np.dtype("bfloat16")`` is not a valid lookup,
# so rebuild goes through the jnp scalar type's dtype object.
def _wire_dtypes() -> dict[str, np.dtype]:
    table: dict[str, np.dtype] = {}
    for t in (np.float32, np.float64, np.float16, np.int8, np.int16,
              np.int32, np.int64, np.uint8, np.uint16, np.uint32,
              np.uint64, np.bool_):
        dt = np.dtype(t)
        table[dt.name] = dt
    for name in ("bfloat16", "float8_e4m3fn", "float8_e5m2"):
        scalar = getattr(jnp, name, None)
        if scalar is not None:
            dt = np.dtype(scalar)
            table[dt.name] = dt
    return table


_WIRE_DTYPES = _wire_dtypes()

# value-stream fields per format: what a ``mode="values"`` record ships when
# the topology (indices / out_index / neuron_active) is unchanged.
VALUE_FIELDS: dict[str, tuple[str, ...]] = {
    "condensed": ("values", "scales"),
    "condensed_over_active": ("values", "scales"),
    "structured": ("values", "scales"),
    "masked": (),
}


@dataclasses.dataclass
class StackDelta:
    """One sparse stack's update at one generation.

    ``mode="topology"`` carries the complete exported leaf (``static`` is the
    format's ``_static_fields`` dict, ``arrays`` every non-None array field);
    ``mode="values"`` carries only the VALUE_FIELDS subset and is merged into
    the subscriber's stored topology record. ``mask_version`` is the
    trainer-side per-stack counter the generation handshake validates
    against.
    """
    name: str
    mask_version: int
    mode: str                      # "topology" | "values"
    format: str                    # formats.FORMATS key
    static: dict
    arrays: dict                   # field -> np.ndarray


@dataclasses.dataclass
class Delta:
    generation: int
    stacks: list[StackDelta]
    dense: dict                    # "/"-joined path -> np.ndarray (params)

    kind = "delta"


@dataclasses.dataclass
class Snapshot:
    generation: int
    meta: dict                     # {"path", "values_dtype", "tp", ["arch"]}
    mask_versions: dict            # stack name -> int
    stacks: list[StackDelta]       # all mode="topology"
    params: dict                   # "/"-joined path -> np.ndarray
    masks: dict                    # "/"-joined path -> np.ndarray

    kind = "snapshot"


# ---------------------------------------------------------------------------
# tree <-> flat dict helpers (stack names are "/"-joined registry paths, so
# the same convention addresses params/masks leaves)
# ---------------------------------------------------------------------------

def flatten_tree(tree, prefix: tuple = ()) -> dict:
    """Nested str-keyed dicts -> {"a/b/c": leaf}. Leaves = non-dict values."""
    flat: dict = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            flat.update(flatten_tree(tree[k], prefix + (str(k),)))
    else:
        flat["/".join(prefix)] = tree
    return flat


def unflatten_tree(flat: dict) -> dict:
    tree: dict = {}
    for path, leaf in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return tree


# ---------------------------------------------------------------------------
# leaf <-> record
# ---------------------------------------------------------------------------

def _np(arr) -> np.ndarray:
    out = np.asarray(arr)
    if out.dtype.name not in _WIRE_DTYPES:
        raise DeltaCorruptError(f"dtype {out.dtype.name!r} not wire-safe")
    return np.ascontiguousarray(out)


def leaf_to_wire(name: str, mask_version: int, leaf,
                 *, mode: str = "topology") -> StackDelta:
    """A formats.py dataclass -> a host-side StackDelta record."""
    fields = (leaf._array_fields if mode == "topology"
              else VALUE_FIELDS[leaf.format_name])
    arrays = {f: _np(getattr(leaf, f)) for f in fields
              if getattr(leaf, f, None) is not None}
    static = {f: getattr(leaf, f) for f in leaf._static_fields}
    return StackDelta(name=name, mask_version=int(mask_version), mode=mode,
                      format=leaf.format_name, static=static, arrays=arrays)


def wire_to_leaf(rec: StackDelta, *, device: bool = True):
    """Rebuild the formats.py dataclass from a topology record."""
    if rec.mode != "topology":
        raise DeltaCorruptError(
            f"stack {rec.name!r}: cannot build a leaf from a "
            f"mode={rec.mode!r} record")
    cls = F.FORMATS.get(rec.format)
    if cls is None:
        raise DeltaCorruptError(f"unknown format {rec.format!r}")
    kw = dict(rec.static)
    for f in cls._array_fields:
        arr = rec.arrays.get(f)
        if arr is not None and device:
            arr = jnp.asarray(arr)
        kw[f] = arr
    return cls(**kw)


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------

def _pack_arrays(groups) -> tuple[list, bytes]:
    """groups: iterable of (section, owner, field, np.ndarray). Returns the
    header array descriptors (in payload order) and the payload bytes."""
    descs, chunks, offset = [], [], 0
    for section, owner, field, arr in groups:
        arr = _np(arr)
        buf = arr.tobytes()
        descs.append({"section": section, "owner": owner, "field": field,
                      "dtype": arr.dtype.name, "shape": list(arr.shape),
                      "offset": offset, "nbytes": len(buf)})
        chunks.append(buf)
        offset += len(buf)
    return descs, b"".join(chunks)


def _iter_record_arrays(obj):
    for sd in obj.stacks:
        for field in sorted(sd.arrays):
            yield "stack", sd.name, field, sd.arrays[field]
    if obj.kind == "delta":
        for path in sorted(obj.dense):
            yield "dense", path, "", obj.dense[path]
    else:
        for path in sorted(obj.params):
            yield "params", path, "", obj.params[path]
        for path in sorted(obj.masks):
            yield "masks", path, "", obj.masks[path]


def encode(obj) -> bytes:
    """Delta | Snapshot -> checksummed wire bytes."""
    descs, payload = _pack_arrays(_iter_record_arrays(obj))
    header = {
        "kind": obj.kind,
        "generation": int(obj.generation),
        "arrays": descs,
        "stacks": [{"name": sd.name, "mask_version": int(sd.mask_version),
                    "mode": sd.mode, "format": sd.format,
                    "static": {k: v for k, v in sd.static.items()}}
                   for sd in obj.stacks],
    }
    if obj.kind == "snapshot":
        header["meta"] = obj.meta
        header["mask_versions"] = {k: int(v)
                                   for k, v in obj.mask_versions.items()}
    hdr = json.dumps(header, sort_keys=True,
                     separators=(",", ":")).encode("utf-8")
    body = hdr + payload
    return (_MAGIC + _LEN.pack(len(hdr), len(payload)) + body
            + _CRC.pack(zlib.crc32(body)))


def decode(blob: bytes):
    """Wire bytes -> Delta | Snapshot. Raises DeltaCorruptError."""
    if not isinstance(blob, (bytes, bytearray, memoryview)):
        raise DeltaCorruptError("not a bytes object")
    blob = bytes(blob)
    if len(blob) < len(_MAGIC) + _LEN.size + _CRC.size:
        raise DeltaCorruptError("record truncated")
    if blob[:4] != _MAGIC:
        raise DeltaCorruptError("bad magic")
    hdr_len, pay_len = _LEN.unpack_from(blob, 4)
    body_start = 4 + _LEN.size
    body_end = body_start + hdr_len + pay_len
    if body_end + _CRC.size != len(blob):
        raise DeltaCorruptError("length mismatch")
    body = blob[body_start:body_end]
    (crc,) = _CRC.unpack_from(blob, body_end)
    if zlib.crc32(body) != crc:
        raise DeltaCorruptError("checksum mismatch")
    try:
        header = json.loads(body[:hdr_len].decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise DeltaCorruptError(f"bad header: {e}") from None
    payload = body[hdr_len:]
    try:
        return _rebuild(header, payload)
    except (KeyError, TypeError, ValueError) as e:
        if isinstance(e, DeltaCorruptError):
            raise
        raise DeltaCorruptError(f"malformed record: {e}") from None


def _rebuild(header: dict, payload: bytes):
    arrays: dict[tuple, np.ndarray] = {}
    for d in header["arrays"]:
        dt = _WIRE_DTYPES.get(d["dtype"])
        if dt is None:
            raise DeltaCorruptError(f"unknown wire dtype {d['dtype']!r}")
        start, nbytes = d["offset"], d["nbytes"]
        buf = payload[start:start + nbytes]
        if len(buf) != nbytes:
            raise DeltaCorruptError("payload truncated")
        arr = np.frombuffer(buf, dtype=dt).reshape(d["shape"])
        arrays[(d["section"], d["owner"], d["field"])] = arr
    stacks = []
    for sd in header["stacks"]:
        stack_arrays = {field: arr
                       for (sec, owner, field), arr in arrays.items()
                       if sec == "stack" and owner == sd["name"]}
        stacks.append(StackDelta(
            name=sd["name"], mask_version=int(sd["mask_version"]),
            mode=sd["mode"], format=sd["format"],
            static=_restore_static(sd["format"], sd["static"]),
            arrays=stack_arrays))
    gen = int(header["generation"])
    if header["kind"] == "delta":
        dense = {owner: arr for (sec, owner, _), arr in arrays.items()
                 if sec == "dense"}
        return Delta(generation=gen, stacks=stacks, dense=dense)
    if header["kind"] == "snapshot":
        params = {owner: arr for (sec, owner, _), arr in arrays.items()
                  if sec == "params"}
        masks = {owner: arr for (sec, owner, _), arr in arrays.items()
                 if sec == "masks"}
        return Snapshot(generation=gen, meta=header["meta"],
                        mask_versions={k: int(v) for k, v in
                                       header["mask_versions"].items()},
                        stacks=stacks, params=params, masks=masks)
    raise DeltaCorruptError(f"unknown record kind {header['kind']!r}")


def _restore_static(format_name: str, static: dict) -> dict:
    """JSON round-trips ints/strings/None fine; just validate the keys
    against the format's declared static fields so a doctored header cannot
    smuggle arbitrary constructor kwargs."""
    cls = F.FORMATS.get(format_name)
    if cls is None:
        raise DeltaCorruptError(f"unknown format {format_name!r}")
    extra = set(static) - set(cls._static_fields)
    if extra:
        raise DeltaCorruptError(
            f"static fields {sorted(extra)} not declared by {format_name}")
    return dict(static)
