"""Trainer-side diff engine: TrainState -> versioned Delta/Snapshot records.

The publisher owns one reference :class:`~repro.sparse.plan.Plan` built at
``batch_size`` with the serving ``path``/``values_dtype``/``tp`` the fleet
runs. Each ``publish(state)``:

1. reads the per-stack ``mask_versions`` counters (one fused host fetch),
2. runs the existing donated ``Plan.refresh`` -- only stacks whose version
   moved are re-condensed, the rest get a values-only refresh (the exported
   condensed leaves ARE the wire payload; no second export path exists),
3. ships a ``Delta``: topology records for moved stacks, values-only records
   for the rest, plus the dense (non-stack) parameter leaves,
4. answers any queued resync requests with a full ``Snapshot``.

Only the condensed family (``condensed`` / ``condensed_over_active``) can be
published: ``masked`` and float ``structured`` leaves read the LIVE training
weights at execution time, so a byte stream of their exported arrays could
never keep a remote replica current.
"""
from __future__ import annotations

import dataclasses
import logging

import jax

from repro.sparse import condensed as COND  # noqa: F401  (re-export surface)
from repro.sparse import plan as PLAN
from repro.sparse import registry as REG
from repro.sync import delta as D

log = logging.getLogger(__name__)

PUBLISHABLE_PATHS = ("condensed", "condensed_over_active")


def _record_bytes(rec: D.StackDelta) -> int:
    return sum(a.nbytes for a in rec.arrays.values())


@dataclasses.dataclass
class Publisher:
    """Publishes one stream of generations onto a channel.

    ``generation`` starts at 0 (nothing published); the first ``publish``
    emits generation 1 as a full ``Snapshot`` so subscribers can bootstrap,
    every later ``publish`` emits a ``Delta``.
    """
    cfg: object
    registry: list
    channel: object
    path: str = "condensed"
    values_dtype: str | None = None
    tp: int = 1
    profile: object = None
    batch_size: int = 1
    arch: str | None = None

    generation: int = dataclasses.field(default=0, init=False)
    last_info: dict = dataclasses.field(default_factory=dict, init=False)
    counters: dict = dataclasses.field(
        default_factory=lambda: {"resync_requests": 0,
                                 "resync_snapshots": 0,
                                 "resync_coalesced": 0}, init=False)
    _plan: object = dataclasses.field(default=None, init=False)
    _params: object = dataclasses.field(default=None, init=False)
    _masks: object = dataclasses.field(default=None, init=False)
    _resync_snapshot_gen: int | None = dataclasses.field(default=None,
                                                         init=False)

    def __post_init__(self):
        if self.path not in PUBLISHABLE_PATHS:
            raise ValueError(
                f"publisher path must be one of {PUBLISHABLE_PATHS}; "
                f"{self.path!r} leaves read live training weights at "
                f"execution time and cannot be streamed")
        if self.profile is None:
            self.profile = PLAN.DEFAULT_PROFILE

    # -- public API ---------------------------------------------------------

    def publish(self, state=None, *, params=None, masks=None,
                mask_versions=None) -> dict:
        """Diff against the last published generation and send one record.

        Accepts a ``TrainState`` or explicit ``params``/``masks``/
        ``mask_versions``. Returns an info dict (kind, generation, byte
        accounting) also stored as ``self.last_info``.
        """
        if state is not None:
            params, masks = state.params, state.masks
            mask_versions = state.mask_versions
        if params is None or masks is None or mask_versions is None:
            raise ValueError("publish needs a TrainState or explicit "
                             "params/masks/mask_versions")
        versions = PLAN._host_versions(mask_versions)
        self._params, self._masks = params, masks

        if self._plan is None:
            self._plan = PLAN.build_plan(
                self.cfg, self.registry, params, masks,
                batch_size=self.batch_size, path=self.path,
                mask_versions=versions, profile=self.profile,
                values_dtype=self.values_dtype, tp=self.tp)
            self.generation = 1
            info = self._send_snapshot()
        else:
            changed = set(self._plan.refresh(params, masks, versions))
            self.generation += 1
            info = self._send_delta(changed, versions, params)
        self.serve_resyncs()
        self.last_info = info
        return info

    def serve_resyncs(self) -> int:
        """Answer queued subscriber resync requests with a full Snapshot at
        the CURRENT generation, coalescing the storm: N requests drained in
        one poll share ONE snapshot publish, and a request whose missing
        generation is already covered by a snapshot previously published at
        the current generation triggers NO publish at all (the record is
        still on the channel -- ``DirChannel`` pruning always retains the
        newest snapshot, so a late requester tails it like everyone else).
        A requester that gaps AGAIN after pruning comes back with a higher
        ``needed_generation`` and gets a fresh snapshot then. Counters:
        ``resync_requests`` (drained), ``resync_snapshots`` (published),
        ``resync_coalesced`` (requests answered without a fresh publish)."""
        requests = self.channel.poll_requests()
        if not requests or self._plan is None:
            return 0
        self.counters["resync_requests"] += len(requests)
        covered = self._resync_snapshot_gen
        if covered is not None and all(
                r.get("needed_generation") is not None
                and r["needed_generation"] <= covered
                for r in requests):
            self.counters["resync_coalesced"] += len(requests)
            log.info("sync: resync storm from %s coalesced onto snapshot "
                     "gen %d already on channel",
                     [r.get("subscriber") for r in requests], covered)
            return len(requests)
        log.info("sync: resync requested by %s -> snapshot gen %d",
                 [r.get("subscriber") for r in requests], self.generation)
        self._send_snapshot()
        self.counters["resync_snapshots"] += 1
        self.counters["resync_coalesced"] += len(requests) - 1
        return len(requests)

    # -- record assembly ----------------------------------------------------

    def _stack_leaves(self) -> dict:
        return {s.name: REG.get_path(self._plan.serving_tree, s.path)
                for s in self.registry}

    def _versions_now(self) -> dict:
        return {k: int(v) for k, v in self._plan.mask_versions.items()}

    def _send_snapshot(self) -> dict:
        # one fused host fetch of everything the record ships
        host = jax.device_get({"leaves": self._stack_leaves(),
                               "params": self._params,
                               "masks": self._masks})
        versions = self._versions_now()
        stacks = [D.leaf_to_wire(name, versions[name], leaf)
                  for name, leaf in host["leaves"].items()]
        meta = {"path": self.path, "values_dtype": self.values_dtype,
                "tp": self.tp}
        if self.arch is not None:
            meta["arch"] = self.arch
        snap = D.Snapshot(generation=self.generation, meta=meta,
                          mask_versions=versions, stacks=stacks,
                          params=D.flatten_tree(host["params"]),
                          masks=D.flatten_tree(host["masks"]))
        blob = D.encode(snap)
        self.channel.send(blob, kind="snapshot", generation=self.generation)
        self._resync_snapshot_gen = self.generation
        return {"kind": "snapshot", "generation": self.generation,
                "bytes": len(blob),
                "topology": sorted(versions), "values_only": [],
                "topology_bytes": sum(_record_bytes(r) for r in stacks),
                "values_bytes": 0,
                "dense_bytes": sum(a.nbytes for a in
                                   snap.params.values())}

    def _send_delta(self, changed: set, versions: dict, params) -> dict:
        stack_names = {s.name for s in self.registry}
        dense_dev = {k: v for k, v in D.flatten_tree(params).items()
                     if k not in stack_names}
        host = jax.device_get({"leaves": self._stack_leaves(),
                               "dense": dense_dev})
        stacks, topo_b, val_b = [], 0, 0
        for name, leaf in host["leaves"].items():
            mode = "topology" if name in changed else "values"
            rec = D.leaf_to_wire(name, versions[name], leaf, mode=mode)
            stacks.append(rec)
            if mode == "topology":
                topo_b += _record_bytes(rec)
            else:
                val_b += _record_bytes(rec)
        delta = D.Delta(generation=self.generation, stacks=stacks,
                        dense=host["dense"])
        blob = D.encode(delta)
        self.channel.send(blob, kind="delta", generation=self.generation)
        return {"kind": "delta", "generation": self.generation,
                "bytes": len(blob),
                "topology": sorted(changed),
                "values_only": sorted(stack_names - changed),
                "topology_bytes": topo_b, "values_bytes": val_b,
                "dense_bytes": sum(a.nbytes for a in
                                   host["dense"].values())}
