"""Live train->serve weight sync: versioned mask-delta publisher/subscriber.

The condensed constant-fan-in export IS the wire format (ROADMAP open item
2; the Graphcore dynamic-sparsity stack ships COO triplets host-side for
the same reason): per-stack topology deltas carry ``indices`` + ``values``
(+ ``scales``/``out_index`` where the leaf has them), stacks whose mask did
not move ship values-only, and a monotonically increasing per-stack
``(mask_version, generation)`` header plus an all-or-nothing generation
commit keeps every subscriber's stacks mutually coherent mid-stream.

Layers:

- :mod:`repro.sync.delta` -- checksummed binary records (``Delta`` /
  ``Snapshot``) that round-trip every ``formats.py`` dataclass, including
  quantized ``values_dtype`` and ``tp``-sharded layouts.
- :mod:`repro.sync.channel` -- an in-process ``QueueChannel`` and a
  multi-process ``DirChannel`` (atomically renamed delta files that
  subscribers tail), both with a resync request back-channel.
- :mod:`repro.sync.publisher` / :mod:`repro.sync.subscriber` -- the
  trainer-side diff engine and the replica-side generation handshake
  (stale deltas dropped, gaps -> full-snapshot resync, never a partial
  apply).

Engine integration lives in ``launch/engine.py``
(``ServingEngine.attach_subscriber``) and ``train/trainer.py``
(``Trainer(publisher=...)``).
"""

from repro.sync.delta import (  # noqa: F401
    Delta,
    DeltaCorruptError,
    Snapshot,
    StackDelta,
    decode,
    encode,
)
from repro.sync.channel import DirChannel, QueueChannel  # noqa: F401
from repro.sync.publisher import Publisher  # noqa: F401
from repro.sync.subscriber import Subscriber, engine_from_snapshot  # noqa: F401
